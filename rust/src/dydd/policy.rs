//! Rebalance policies for multi-cycle assimilation: *when* to re-run DyDD.
//!
//! The paper's framework re-defines subdomain boundaries "as the
//! observation distribution changes" — across successive assimilation
//! cycles, not just once before a single solve. A [`RebalancePolicy`]
//! decides, at the start of each cycle, whether the incumbent partition is
//! still good enough or DyDD should migrate boundaries again (warm-started
//! from the incumbent decomposition). The trade-off it encodes is the
//! paper's T_DyDD overhead versus the load-imbalance overhead T^p_oh.

/// When the cycle driver re-runs DyDD.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RebalancePolicy {
    /// Never rebalance: the initial (uniform) partition is kept for all
    /// cycles — the static-DD baseline the paper argues against.
    Never,
    /// Rebalance before every cycle regardless of the incumbent balance —
    /// maximal quality, maximal T_DyDD overhead.
    EveryCycle,
    /// Rebalance only when the balance ratio ℰ of the *current* cycle's
    /// census under the incumbent partition drops below τ ∈ (0, 1].
    Threshold(f64),
}

impl RebalancePolicy {
    /// The default trigger level: re-run DyDD once the incumbent partition
    /// loses more than 10% of perfect balance.
    pub const DEFAULT_TAU: f64 = 0.9;

    /// Decide whether this cycle rebalances, given ℰ of the new census
    /// under the incumbent partition.
    pub fn should_rebalance(&self, balance_before: f64) -> bool {
        match *self {
            RebalancePolicy::Never => false,
            RebalancePolicy::EveryCycle => true,
            RebalancePolicy::Threshold(tau) => balance_before < tau,
        }
    }

    /// Parse a CLI / config name: `never`, `every_cycle` (or `every`),
    /// `threshold` (τ = [`Self::DEFAULT_TAU`]) or `threshold:0.85`.
    pub fn parse(s: &str) -> Option<RebalancePolicy> {
        let lower = s.to_ascii_lowercase();
        Some(match lower.as_str() {
            "never" => RebalancePolicy::Never,
            "every_cycle" | "everycycle" | "every" => RebalancePolicy::EveryCycle,
            "threshold" => RebalancePolicy::Threshold(Self::DEFAULT_TAU),
            _ => {
                let tau = lower.strip_prefix("threshold:")?.parse::<f64>().ok()?;
                if !(tau > 0.0 && tau <= 1.0) {
                    return None;
                }
                RebalancePolicy::Threshold(tau)
            }
        })
    }

    /// The canonical config-file name (round-trips through [`Self::parse`]).
    pub fn name(&self) -> String {
        match *self {
            RebalancePolicy::Never => "never".into(),
            RebalancePolicy::EveryCycle => "every_cycle".into(),
            RebalancePolicy::Threshold(tau) => format!("threshold:{tau}"),
        }
    }

    /// Replace the threshold τ (no-op for the other policies).
    pub fn with_tau(self, tau: f64) -> RebalancePolicy {
        match self {
            RebalancePolicy::Threshold(_) => RebalancePolicy::Threshold(tau),
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_match_semantics() {
        assert!(!RebalancePolicy::Never.should_rebalance(0.0));
        assert!(RebalancePolicy::EveryCycle.should_rebalance(1.0));
        let t = RebalancePolicy::Threshold(0.8);
        assert!(t.should_rebalance(0.79));
        assert!(!t.should_rebalance(0.8));
        assert!(!t.should_rebalance(0.95));
    }

    #[test]
    fn parse_roundtrips() {
        for p in [
            RebalancePolicy::Never,
            RebalancePolicy::EveryCycle,
            RebalancePolicy::Threshold(0.75),
        ] {
            assert_eq!(RebalancePolicy::parse(&p.name()), Some(p));
        }
        assert_eq!(
            RebalancePolicy::parse("threshold"),
            Some(RebalancePolicy::Threshold(RebalancePolicy::DEFAULT_TAU))
        );
        assert_eq!(RebalancePolicy::parse("every"), Some(RebalancePolicy::EveryCycle));
        assert_eq!(RebalancePolicy::parse("threshold:0"), None);
        assert_eq!(RebalancePolicy::parse("threshold:1.5"), None);
        assert_eq!(RebalancePolicy::parse("sometimes"), None);
    }
}
