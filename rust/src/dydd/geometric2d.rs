//! Geometric DyDD on box grids: realize the Hu–Blake–Emerson schedule by
//! shifting box edges along each axis (the 2-D Migration + Update steps).
//!
//! The abstract balancer ([`balance`]) runs on the box grid's 4-connected
//! decomposition graph unchanged and decides the target census l_fin per
//! box (with the DD repair step splitting the max-load neighbour of every
//! empty box). Realization then happens axis by axis:
//!
//! 1. **x sweep** — global column bounds are re-chosen so each of the `px`
//!    columns holds its scheduled column total Σ_by l_fin(bx, by)
//!    (a 1-D boundary-shifting problem on the x marginal, solved by
//!    [`Partition::from_targets`]).
//! 2. **y sweep** — every column independently re-chooses its `py` row
//!    bounds so box (bx, by) holds l_fin(bx, by) of the column's
//!    observations (per-column bounds are what make an *arbitrary* —
//!    including non-separable — census realizable; a pure tensor-product
//!    split can only balance separable densities).
//!
//! Exactness caveat (same as 1-D): several observations can share a grid
//! point and a box edge cannot split them, so each realized count can
//! deviate from l_fin by up to the largest grid-line multiplicity per axis.

use super::balancer::{balance, BalanceError, DyddOutcome, DyddParams};
use crate::domain::Partition;
use crate::domain2d::{BoxPartition, Mesh2d, ObservationSet2d};
use std::time::Instant;

/// Outcome of a 2-D geometric rebalance.
#[derive(Debug, Clone)]
pub struct GeometricOutcome2d {
    /// The abstract balancing record (schedule targets, migrations,
    /// timings, repair trace).
    pub dydd: DyddOutcome,
    /// The re-mapped box partition realizing the schedule.
    pub partition: BoxPartition,
    /// Realized census after edge shifting (Update step).
    pub census_after: Vec<usize>,
}

impl GeometricOutcome2d {
    /// Realized load-balance ratio ℰ.
    pub fn balance(&self) -> f64 {
        super::balance_ratio(&self.census_after)
    }
}

/// Run DyDD on the census of `obs` under `part` and shift box edges along
/// both axes to realize the balanced loads.
pub fn rebalance_partition2d(
    mesh: &Mesh2d,
    part: &BoxPartition,
    obs: &ObservationSet2d,
    params: &DyddParams,
) -> Result<GeometricOutcome2d, BalanceError> {
    // One nearest-point pass serves the initial census, both sweeps and
    // the final census.
    let grid = obs.grid_indices(mesh);
    let census_of = |p: &BoxPartition| {
        let mut c = vec![0usize; p.p()];
        for &(ix, iy) in &grid {
            c[p.owner(ix, iy)] += 1;
        }
        c
    };
    let census = census_of(part);
    let g = part.induced_graph();
    let t0 = Instant::now();
    let mut outcome = balance(&g, &census, params)?;

    let (px, py) = (part.px(), part.py());

    // x sweep: global column bounds from the scheduled column totals.
    let col_targets: Vec<usize> = (0..px)
        .map(|bx| (0..py).map(|by| outcome.l_fin[part.box_id(bx, by)]).sum())
        .collect();
    let gx: Vec<usize> = grid.iter().map(|&(ix, _)| ix).collect();
    let xbounds = Partition::from_targets(mesh.nx(), &gx, &col_targets)
        .bounds()
        .to_vec();

    // y sweep: per-column row bounds from the scheduled box loads,
    // re-apportioned to the column's *realized* count (x-axis tie groups
    // can make it deviate from the scheduled column total).
    let mut ybounds = Vec::with_capacity(px);
    for bx in 0..px {
        // gx is non-decreasing, so each column is a contiguous slice.
        let (lo, hi) = (xbounds[bx], xbounds[bx + 1]);
        let a = gx.partition_point(|&g| g < lo);
        let b = gx.partition_point(|&g| g < hi);
        let mut ys: Vec<usize> = grid[a..b].iter().map(|&(_, iy)| iy).collect();
        ys.sort_unstable();
        let template: Vec<usize> =
            (0..py).map(|by| outcome.l_fin[part.box_id(bx, by)]).collect();
        let row_targets = apportion(&template, ys.len());
        let col_bounds = Partition::from_targets(mesh.ny(), &ys, &row_targets)
            .bounds()
            .to_vec();
        ybounds.push(col_bounds);
    }

    let partition = BoxPartition::from_bounds(mesh.nx(), mesh.ny(), xbounds, ybounds);
    let census_after = census_of(&partition);
    // Edge shifting is part of the migration step the paper times.
    outcome.t_dydd = outcome.t_dydd.max(t0.elapsed());

    Ok(GeometricOutcome2d { dydd: outcome, partition, census_after })
}

/// Largest-remainder apportionment: distribute `m` proportionally to
/// `template` (uniformly when the template is all-zero), summing to `m`
/// exactly.
fn apportion(template: &[usize], m: usize) -> Vec<usize> {
    let p = template.len();
    let total: usize = template.iter().sum();
    if total == 0 {
        let mut out = vec![m / p; p];
        for slot in out.iter_mut().take(m % p) {
            *slot += 1;
        }
        return out;
    }
    let mut out: Vec<usize> = template.iter().map(|&t| t * m / total).collect();
    let assigned: usize = out.iter().sum();
    // Hand the remainder (< p) to the largest fractional parts,
    // deterministically (ties by index).
    let mut rem: Vec<(usize, usize)> =
        template.iter().enumerate().map(|(i, &t)| ((t * m) % total, i)).collect();
    rem.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, i) in rem.iter().take(m - assigned) {
        out[i] += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain2d::generators::{self, ObsLayout2d};
    use crate::util::Rng;

    fn setup(
        n: usize,
        px: usize,
        py: usize,
        layout: ObsLayout2d,
        m: usize,
        seed: u64,
    ) -> (Mesh2d, BoxPartition, ObservationSet2d) {
        let mesh = Mesh2d::square(n);
        let part = BoxPartition::uniform(n, n, px, py);
        let mut rng = Rng::new(seed);
        let obs = generators::generate(layout, m, &mut rng);
        (mesh, part, obs)
    }

    #[test]
    fn apportion_sums_and_spreads() {
        assert_eq!(apportion(&[1, 1, 1, 1], 10).iter().sum::<usize>(), 10);
        assert_eq!(apportion(&[0, 0, 0], 7), vec![3, 2, 2]);
        assert_eq!(apportion(&[100, 0], 99), vec![99, 0]);
        let a = apportion(&[3, 1], 8);
        assert_eq!(a, vec![6, 2]);
    }

    #[test]
    fn gaussian_blob_4x4_reaches_acceptance_balance() {
        // The acceptance scenario: 4 × 4 boxes, clustered blob. Initial
        // ℰ ≤ 0.2 (corner boxes are empty), final ℰ ≥ 0.8.
        let (mesh, part, obs) = setup(512, 4, 4, ObsLayout2d::GaussianBlob, 2000, 42);
        let before = super::super::balance_ratio(&obs.census(&mesh, &part));
        assert!(before <= 0.2, "initial balance {before}");
        let out = rebalance_partition2d(&mesh, &part, &obs, &DyddParams::default()).unwrap();
        assert_eq!(out.census_after.iter().sum::<usize>(), 2000);
        assert!(out.balance() >= 0.8, "final census {:?}", out.census_after);
    }

    #[test]
    fn quadrant_exercises_dd_repair() {
        // ¾ of the 2 × 2 grid starts empty: the DD repair step must run
        // (l_r recorded), then migration balances the boxes.
        let (mesh, part, obs) = setup(256, 2, 2, ObsLayout2d::Quadrant, 600, 7);
        let census = obs.census(&mesh, &part);
        assert_eq!(census.iter().filter(|&&c| c == 0).count(), 3, "{census:?}");
        let out = rebalance_partition2d(&mesh, &part, &obs, &DyddParams::default()).unwrap();
        assert!(out.dydd.l_r.is_some(), "repair step must have run");
        assert_eq!(out.dydd.l_fin, vec![150, 150, 150, 150]);
        assert_eq!(out.census_after.iter().sum::<usize>(), 600);
        assert!(out.balance() > 0.8, "final census {:?}", out.census_after);
    }

    #[test]
    fn non_separable_layouts_balance_via_per_column_bounds() {
        // DiagonalBand and Ring have uniform marginals but clustered joint
        // density — only the per-column y sweep can balance them.
        for (layout, seed) in [(ObsLayout2d::DiagonalBand, 8), (ObsLayout2d::Ring, 9)] {
            let (mesh, part, obs) = setup(512, 4, 4, layout, 2000, seed);
            let out =
                rebalance_partition2d(&mesh, &part, &obs, &DyddParams::default()).unwrap();
            assert_eq!(out.census_after.iter().sum::<usize>(), 2000, "{layout:?}");
            assert!(out.balance() >= 0.8, "{layout:?}: {:?}", out.census_after);
        }
    }

    #[test]
    fn census_after_tracks_l_fin_within_tie_groups() {
        let (mesh, part, obs) = setup(256, 4, 2, ObsLayout2d::GaussianBlob, 800, 10);
        let out = rebalance_partition2d(&mesh, &part, &obs, &DyddParams::default()).unwrap();
        let grid = obs.grid_indices(&mesh);
        // Largest multiplicity of a grid line per axis bounds the
        // realizable deviation (see module docs); +1 for re-apportionment.
        let max_mult = |vals: &mut Vec<usize>| {
            vals.sort_unstable();
            let (mut best, mut run) = (1usize, 1usize);
            for w in vals.windows(2) {
                run = if w[0] == w[1] { run + 1 } else { 1 };
                best = best.max(run);
            }
            best
        };
        let mut gx: Vec<usize> = grid.iter().map(|&(ix, _)| ix).collect();
        let mut gy: Vec<usize> = grid.iter().map(|&(_, iy)| iy).collect();
        let bound = max_mult(&mut gx) + max_mult(&mut gy) + 1;
        for (got, want) in out.census_after.iter().zip(&out.dydd.l_fin) {
            assert!(
                got.abs_diff(*want) <= bound,
                "census {:?} vs target {:?} (bound {bound})",
                out.census_after,
                out.dydd.l_fin
            );
        }
    }

    #[test]
    fn single_row_and_single_column_grids() {
        // py = 1 degenerates to a pure x split; px = 1 to a single-column
        // y split — both must still balance.
        for (px, py) in [(6usize, 1usize), (1, 6)] {
            let (mesh, part, obs) = setup(512, px, py, ObsLayout2d::GaussianBlob, 1200, 11);
            let out =
                rebalance_partition2d(&mesh, &part, &obs, &DyddParams::default()).unwrap();
            assert_eq!(out.census_after.iter().sum::<usize>(), 1200, "{px}x{py}");
            assert!(out.balance() >= 0.85, "{px}x{py}: {:?}", out.census_after);
        }
    }
}
