//! Minimal TOML-subset parser: `[table]` headers, `key = value` pairs with
//! string / integer / float / boolean / homogeneous-array values, `#`
//! comments. Enough for `configs/*.toml`; unknown syntax fails loudly.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

#[derive(Debug, thiserror::Error)]
#[error("toml parse error at line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// Parse into `table.key -> value` (root keys have no prefix).
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut out = BTreeMap::new();
    let mut prefix = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated table header"))?;
            let name = name.trim();
            if name.is_empty() || name.contains('[') {
                return Err(err("bad table name"));
            }
            prefix = format!("{name}.");
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
        let key = key.trim();
        if key.is_empty() || key.contains(' ') {
            return Err(err("bad key"));
        }
        let value = parse_value(value.trim()).map_err(|m| err(&m))?;
        out.insert(format!("{prefix}{key}"), value);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote not supported".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<_>, _> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Array(items?));
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_types() {
        let text = r#"
# experiment config
name = "ex1"            # inline comment
[problem]
n = 2048
mu = 1.5e-3
layouts = ["uniform", "cluster"]
sizes = [2, 4, 8]
[run]
parallel = true
"#;
        let t = parse_toml(text).unwrap();
        assert_eq!(t["name"].as_str(), Some("ex1"));
        assert_eq!(t["problem.n"].as_usize(), Some(2048));
        assert_eq!(t["problem.mu"].as_float(), Some(1.5e-3));
        assert_eq!(t["run.parallel"].as_bool(), Some(true));
        assert_eq!(t["problem.sizes"].as_array().unwrap().len(), 3);
    }

    #[test]
    fn reports_line_numbers() {
        let err = parse_toml("x = 1\ny 2").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(parse_toml("x = ").is_err());
        assert!(parse_toml("x = \"open").is_err());
        assert!(parse_toml("[t\nx = 1").is_err());
        assert!(parse_toml("x = [1, 2").is_err());
    }

    #[test]
    fn underscores_in_numbers() {
        let t = parse_toml("big = 1_000_000").unwrap();
        assert_eq!(t["big"].as_int(), Some(1_000_000));
    }
}
