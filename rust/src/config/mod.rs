//! Configuration system: a TOML-subset parser (tables, strings, numbers,
//! booleans, arrays — serde/toml are unavailable in this offline build) and
//! the typed experiment configuration with validation.

mod spec;
mod toml;

pub use spec::{ExperimentConfig, StateOpConfig, StreamSourceConfig, ValidationError};
pub use toml::{parse_toml, TomlError, TomlValue};
