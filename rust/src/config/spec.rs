//! Typed experiment configuration with validation, loadable from TOML
//! (`configs/*.toml`) or built programmatically.

use super::toml::{parse_toml, TomlError, TomlValue};
use crate::coordinator::SolverBackend;
use crate::ddkf::{SchwarzOptions, SweepOrder};
use crate::decomp::registry::{self, DriftSpec, LayoutSpec};
use crate::decomp::{BoxGeometry, IntervalGeometry, WindowGeometry};
use crate::domain::{DriftLayout, ObsLayout};
use crate::domain2d::{DriftLayout2d, ObsLayout2d};
use crate::dydd::RebalancePolicy;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// State-operator choice in configs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StateOpConfig {
    Identity,
    Tridiag { main: f64, off: f64 },
}

impl StateOpConfig {
    pub fn build(&self) -> crate::cls::StateOp {
        match *self {
            StateOpConfig::Identity => crate::cls::StateOp::Identity,
            StateOpConfig::Tridiag { main, off } => crate::cls::StateOp::Tridiag { main, off },
        }
    }

    /// The 2-D analogue: `Tridiag` maps to the 5-point stencil with the
    /// same coefficients. One mapping shared by the single-shot and
    /// multi-cycle 2-D pipelines so they can never diverge.
    pub fn build2d(&self) -> crate::cls::StateOp2d {
        match *self {
            StateOpConfig::Identity => crate::cls::StateOp2d::Identity,
            StateOpConfig::Tridiag { main, off } => crate::cls::StateOp2d::FivePoint { main, off },
        }
    }
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    /// Decomposition dimension: 1 (interval decomposition, the paper's
    /// CLS solver path), 2 (box-grid DyDD on [0, 1]²) or 4 (space-time
    /// windows over the stacked trajectory — PinT).
    pub dim: usize,
    /// Mesh size n (per axis when dim = 2: the grid is n × n; the
    /// *spatial* mesh when dim = 4: the trajectory has n × steps
    /// unknowns).
    pub n: usize,
    /// Observation count m (total across time levels when dim = 4).
    pub m: usize,
    /// Subdomain / worker count p (dim = 1); the time-window count when
    /// dim = 4.
    pub p: usize,
    /// Time levels N of the dim-4 trajectory (ignored otherwise).
    pub steps: usize,
    /// Model-constraint weight (Q⁻¹ scalar) of the dim-4 trajectory CLS.
    pub model_weight: f64,
    /// Box grid extents (dim = 2): px × py boxes.
    pub px: usize,
    pub py: usize,
    pub layout: ObsLayout,
    /// 2-D observation layout (dim = 2).
    pub layout2d: ObsLayout2d,
    pub state_op: StateOpConfig,
    /// State weight (R0 diagonal).
    pub state_weight: f64,
    pub seed: u64,
    pub schwarz: SchwarzOptions,
    pub backend: SolverBackend,
    pub artifacts_dir: PathBuf,
    /// Run DyDD before solving.
    pub dydd: bool,
    /// Assimilation cycles K for the multi-cycle driver (`cycle`
    /// subcommand / `harness::run_cycles`); single-shot runs ignore it.
    pub cycles: usize,
    /// When the cycle driver re-runs DyDD (`run.dydd = false` forces
    /// Never).
    pub cycle_policy: RebalancePolicy,
    /// Drifting observation generator for 1-D cycle runs.
    pub drift: DriftLayout,
    /// Drifting observation generator for 2-D cycle runs.
    pub drift2d: DriftLayout2d,
    /// Tick count K for the streaming engine (`serve` subcommand).
    pub ticks: usize,
    /// Where `serve` reads observation deltas from.
    pub stream_source: StreamSourceConfig,
    /// Feed each tick's analysis forward as the next background.
    pub stream_feed_forward: bool,
    /// Warm-start retained blocks from the cached solution.
    pub stream_warm_start: bool,
    /// Diagnostic: disable the incremental path (every tick cold-solves).
    pub stream_force_cold: bool,
    /// Kernel threads for the parallel gram/matmul kernels
    /// (`[perf] threads` / `--threads`). 0 = inherit the process default
    /// (the `DYDD_THREADS` environment variable, else 1). The deterministic
    /// banding contract means this knob can never change a result, only
    /// wall-clock.
    pub threads: usize,
    /// Same-shape batched dispatch mode (`[perf] batch` / `--batch`).
    /// `None` = inherit the process default (the `DYDD_BATCH` environment
    /// variable, else auto). Like `threads`, the bitwise batched ≡
    /// per-block contract means this knob can never change a result.
    pub batch: Option<crate::util::batch::BatchMode>,
    /// Coordinator pool width W (`[perf] workers` / `--workers`): how many
    /// worker threads host the p subdomain blocks. 0 = inherit the process
    /// default (the `DYDD_WORKERS` environment variable, else
    /// min(p, available cores)). Bitwise-neutral at any W.
    pub workers: usize,
    /// Leader ↔ worker iterate-exchange wire format (`[perf] comm` /
    /// `--comm`). `None` = inherit the process default (the `DYDD_COMM`
    /// environment variable, else delta). All modes are bitwise-identical
    /// on the analysis; they differ only in bytes shipped per sweep.
    pub comm: Option<crate::util::comm::CommMode>,
}

/// Delta source for the streaming engine's `serve` loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamSourceConfig {
    /// The geometry's native per-tick record emitter (sparse deltas from
    /// persistent row identities); falls back to `Replay` when the
    /// geometry has none.
    Drift,
    /// Replay `cycle_obs` per tick and diff consecutive sets.
    Replay,
    /// JSONL delta lines on stdin.
    Stdin,
}

impl StreamSourceConfig {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "drift" => Some(StreamSourceConfig::Drift),
            "replay" => Some(StreamSourceConfig::Replay),
            "-" | "stdin" => Some(StreamSourceConfig::Stdin),
            _ => None,
        }
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            dim: 1,
            n: 2048,
            m: 1500,
            p: 4,
            steps: 8,
            model_weight: 5.0,
            px: 2,
            py: 2,
            layout: ObsLayout::Uniform,
            layout2d: ObsLayout2d::Uniform2d,
            state_op: StateOpConfig::Tridiag { main: 1.0, off: 0.15 },
            state_weight: 4.0,
            seed: 42,
            schwarz: SchwarzOptions::default(),
            backend: SolverBackend::Native,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            dydd: true,
            cycles: 8,
            cycle_policy: RebalancePolicy::Threshold(RebalancePolicy::DEFAULT_TAU),
            drift: DriftLayout::TranslatingBlob,
            drift2d: DriftLayout2d::TranslatingBlob,
            ticks: 16,
            stream_source: StreamSourceConfig::Drift,
            stream_feed_forward: true,
            stream_warm_start: true,
            stream_force_cold: false,
            threads: 0,
            batch: None,
            workers: 0,
            comm: None,
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ValidationError {
    #[error("io error reading {path}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },
    #[error(transparent)]
    Toml(#[from] TomlError),
    #[error("config invalid: {0}")]
    Invalid(String),
}

impl ExperimentConfig {
    pub fn from_toml_str(text: &str) -> Result<Self, ValidationError> {
        let t = parse_toml(text)?;
        Self::from_table(&t)
    }

    pub fn from_file(path: &Path) -> Result<Self, ValidationError> {
        let text = std::fs::read_to_string(path)
            .map_err(|source| ValidationError::Io { path: path.to_path_buf(), source })?;
        Self::from_toml_str(&text)
    }

    fn from_table(t: &BTreeMap<String, TomlValue>) -> Result<Self, ValidationError> {
        let mut cfg = ExperimentConfig::default();
        let bad = |k: &str| ValidationError::Invalid(format!("bad value for {k}"));
        // Layout and drift names are dimension-sensitive; resolve them
        // after all keys (including `dim`) are known. The threshold τ is
        // policy-sensitive in the same way.
        let mut layout_name: Option<String> = None;
        let mut drift_name: Option<String> = None;
        let mut cycle_tau: Option<f64> = None;
        for (k, v) in t {
            match k.as_str() {
                "name" => cfg.name = v.as_str().ok_or_else(|| bad(k))?.to_string(),
                "problem.n" => cfg.n = v.as_usize().ok_or_else(|| bad(k))?,
                "problem.m" => cfg.m = v.as_usize().ok_or_else(|| bad(k))?,
                "problem.p" => cfg.p = v.as_usize().ok_or_else(|| bad(k))?,
                "problem.dim" => cfg.dim = v.as_usize().ok_or_else(|| bad(k))?,
                "problem.px" => cfg.px = v.as_usize().ok_or_else(|| bad(k))?,
                "problem.py" => cfg.py = v.as_usize().ok_or_else(|| bad(k))?,
                "problem.steps" => cfg.steps = v.as_usize().ok_or_else(|| bad(k))?,
                "problem.model_weight" => {
                    cfg.model_weight = v.as_float().ok_or_else(|| bad(k))?
                }
                "problem.layout" => {
                    layout_name = Some(v.as_str().ok_or_else(|| bad(k))?.to_string());
                }
                "problem.seed" => cfg.seed = v.as_int().ok_or_else(|| bad(k))? as u64,
                "problem.state_weight" => {
                    cfg.state_weight = v.as_float().ok_or_else(|| bad(k))?
                }
                "problem.state_op" => {
                    cfg.state_op = match v.as_str().ok_or_else(|| bad(k))? {
                        "identity" => StateOpConfig::Identity,
                        "tridiag" => StateOpConfig::Tridiag { main: 1.0, off: 0.15 },
                        other => {
                            return Err(ValidationError::Invalid(format!(
                                "unknown state_op {other:?}"
                            )))
                        }
                    }
                }
                "problem.tridiag_main" => {
                    if let StateOpConfig::Tridiag { ref mut main, .. } = cfg.state_op {
                        *main = v.as_float().ok_or_else(|| bad(k))?;
                    }
                }
                "problem.tridiag_off" => {
                    if let StateOpConfig::Tridiag { ref mut off, .. } = cfg.state_op {
                        *off = v.as_float().ok_or_else(|| bad(k))?;
                    }
                }
                "schwarz.overlap" => cfg.schwarz.overlap = v.as_usize().ok_or_else(|| bad(k))?,
                "schwarz.mu" => cfg.schwarz.mu = v.as_float().ok_or_else(|| bad(k))?,
                "schwarz.tol" => cfg.schwarz.tol = v.as_float().ok_or_else(|| bad(k))?,
                "schwarz.max_iters" => {
                    cfg.schwarz.max_iters = v.as_usize().ok_or_else(|| bad(k))?
                }
                "schwarz.order" => {
                    cfg.schwarz.order = match v.as_str().ok_or_else(|| bad(k))? {
                        "multiplicative" => SweepOrder::Multiplicative,
                        "red_black" | "redblack" => SweepOrder::RedBlack,
                        other => {
                            return Err(ValidationError::Invalid(format!(
                                "unknown sweep order {other:?}"
                            )))
                        }
                    }
                }
                "run.backend" => {
                    cfg.backend = v
                        .as_str()
                        .and_then(SolverBackend::parse)
                        .ok_or_else(|| bad(k))?
                }
                "run.artifacts_dir" => {
                    cfg.artifacts_dir = PathBuf::from(v.as_str().ok_or_else(|| bad(k))?)
                }
                "run.dydd" => cfg.dydd = v.as_bool().ok_or_else(|| bad(k))?,
                "cycle.count" => cfg.cycles = v.as_usize().ok_or_else(|| bad(k))?,
                "cycle.policy" => {
                    cfg.cycle_policy = v
                        .as_str()
                        .and_then(RebalancePolicy::parse)
                        .ok_or_else(|| bad(k))?
                }
                "cycle.tau" => cycle_tau = Some(v.as_float().ok_or_else(|| bad(k))?),
                "cycle.drift" => {
                    drift_name = Some(v.as_str().ok_or_else(|| bad(k))?.to_string());
                }
                "stream.ticks" => cfg.ticks = v.as_usize().ok_or_else(|| bad(k))?,
                "stream.source" => {
                    cfg.stream_source = v
                        .as_str()
                        .and_then(StreamSourceConfig::parse)
                        .ok_or_else(|| bad(k))?
                }
                "stream.feed_forward" => {
                    cfg.stream_feed_forward = v.as_bool().ok_or_else(|| bad(k))?
                }
                "stream.warm_start" => {
                    cfg.stream_warm_start = v.as_bool().ok_or_else(|| bad(k))?
                }
                "stream.force_cold" => {
                    cfg.stream_force_cold = v.as_bool().ok_or_else(|| bad(k))?
                }
                "perf.threads" => cfg.threads = v.as_usize().ok_or_else(|| bad(k))?,
                "perf.batch" => {
                    cfg.batch = Some(
                        v.as_str()
                            .and_then(crate::util::batch::BatchMode::parse)
                            .ok_or_else(|| bad(k))?,
                    )
                }
                "perf.workers" => cfg.workers = v.as_usize().ok_or_else(|| bad(k))?,
                "perf.comm" => {
                    cfg.comm = Some(
                        v.as_str()
                            .and_then(crate::util::comm::CommMode::parse)
                            .ok_or_else(|| bad(k))?,
                    )
                }
                other => {
                    return Err(ValidationError::Invalid(format!("unknown key {other:?}")))
                }
            }
        }
        // Resolve layout and drift names against the final dimension
        // through the shared geometry registry, so a wrong-dimension name
        // errors loudly (with the valid names listed) instead of silently
        // running the default layout — one validation path shared with the
        // CLI.
        if let Some(s) = layout_name {
            match registry::parse_layout(cfg.dim, &s)
                .map_err(|e| ValidationError::Invalid(e.to_string()))?
            {
                LayoutSpec::D1(l) => cfg.layout = l,
                LayoutSpec::D2(l) => cfg.layout2d = l,
            }
        }
        if let Some(s) = drift_name {
            match registry::parse_drift(cfg.dim, &s)
                .map_err(|e| ValidationError::Invalid(e.to_string()))?
            {
                DriftSpec::D1(d) => cfg.drift = d,
                DriftSpec::D2(d) => cfg.drift2d = d,
            }
        }
        if let Some(tau) = cycle_tau {
            if !(tau > 0.0 && tau <= 1.0) {
                return Err(ValidationError::Invalid(format!(
                    "cycle.tau = {tau} out of (0, 1]"
                )));
            }
            if !matches!(cfg.cycle_policy, RebalancePolicy::Threshold(_)) {
                return Err(ValidationError::Invalid(
                    "cycle.tau is only meaningful with cycle.policy = \"threshold\"".into(),
                ));
            }
            cfg.cycle_policy = cfg.cycle_policy.with_tau(tau);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), ValidationError> {
        let fail = |m: String| Err(ValidationError::Invalid(m));
        if self.n < 4 {
            return fail(format!("n = {} too small", self.n));
        }
        if !registry::DIMS.contains(&self.dim) {
            return fail(format!(
                "dim = {} has no registered geometry (valid: 1, 2, 4)",
                self.dim
            ));
        }
        if self.dim == 4 {
            if self.steps == 0 {
                return fail("steps = 0: the trajectory needs at least one time level".into());
            }
            if self.p == 0 || self.p > self.steps {
                return fail(format!(
                    "p = {} time windows cannot decompose steps = {} time levels \
                     (need 1 <= p <= steps; pass --steps/--p or [problem] steps)",
                    self.p, self.steps
                ));
            }
            if self.model_weight <= 0.0 {
                return fail("model_weight must be positive".into());
            }
        }
        if self.dim == 2 {
            if self.px == 0 || self.px > self.n / 2 {
                return fail(format!("px = {} out of range for n = {}", self.px, self.n));
            }
            if self.py == 0 || self.py > self.n / 2 {
                return fail(format!("py = {} out of range for n = {}", self.py, self.n));
            }
        }
        // p is the 1-D subdomain count; the 2-D path uses px × py instead,
        // so don't reject a 2-D config over a field it never reads.
        if self.dim == 1 && (self.p == 0 || self.p > self.n / 2) {
            return fail(format!("p = {} out of range for n = {}", self.p, self.n));
        }
        if self.m == 0 {
            return fail("m = 0: nothing to assimilate".into());
        }
        if self.state_weight <= 0.0 {
            return fail("state_weight must be positive".into());
        }
        if self.schwarz.tol <= 0.0 || self.schwarz.max_iters == 0 {
            return fail("bad schwarz tolerance/iteration budget".into());
        }
        if self.schwarz.mu < 0.0 {
            return fail("mu must be >= 0".into());
        }
        if self.dim == 1 && self.schwarz.overlap > self.n / (2 * self.p).max(1) {
            return fail(format!(
                "overlap {} exceeds half a subdomain (n/p = {})",
                self.schwarz.overlap,
                self.n / self.p
            ));
        }
        if self.dim == 4 && self.schwarz.overlap > self.n / 2 {
            return fail(format!(
                "overlap {} exceeds half a time level (n = {})",
                self.schwarz.overlap, self.n
            ));
        }
        if self.dim == 2 && self.schwarz.overlap > self.n / (2 * self.px.max(self.py)).max(1) {
            return fail(format!(
                "overlap {} exceeds half a box (n/max(px,py) = {})",
                self.schwarz.overlap,
                self.n / self.px.max(self.py)
            ));
        }
        if self.cycles == 0 {
            return fail("cycle.count = 0: nothing to assimilate".into());
        }
        if self.ticks == 0 {
            return fail("stream.ticks = 0: nothing to serve".into());
        }
        if let RebalancePolicy::Threshold(tau) = self.cycle_policy {
            if !(tau > 0.0 && tau <= 1.0) {
                return fail(format!("threshold tau = {tau} out of (0, 1]"));
            }
        }
        if self.threads > 1024 {
            return fail(format!("perf.threads = {} is not a plausible core count", self.threads));
        }
        if self.workers > 1024 {
            return fail(format!(
                "perf.workers = {} is not a plausible pool width",
                self.workers
            ));
        }
        Ok(())
    }

    /// Install this config's kernel-thread knob into the process-global
    /// setting the parallel kernels read. `threads = 0` keeps the process
    /// default (`DYDD_THREADS`, else serial). Called by every run entry
    /// point (run/cycle/serve), so a config's `[perf] threads` takes
    /// effect no matter which driver loads it.
    pub fn apply_threads(&self) {
        if self.threads > 0 {
            crate::util::threads::set_threads(self.threads);
        }
    }

    /// Install this config's batched-dispatch mode into the process-global
    /// knob the dispatch layers read. `None` keeps the process default
    /// (`DYDD_BATCH`, else auto). Called by every run entry point, like
    /// [`ExperimentConfig::apply_threads`].
    pub fn apply_batch(&self) {
        if let Some(m) = self.batch {
            crate::util::batch::set_batch_mode(m);
        }
    }

    /// Install this config's pool-width knob into the process-global
    /// setting new [`crate::coordinator::WorkerPool`]s resolve against.
    /// `workers = 0` keeps the process default (`DYDD_WORKERS`, else
    /// min(p, available cores)).
    pub fn apply_workers(&self) {
        if self.workers > 0 {
            crate::util::workers::set_workers(self.workers);
        }
    }

    /// Install this config's comm-mode knob into the process-global
    /// setting the leader's dispatch loop reads. `None` keeps the process
    /// default (`DYDD_COMM`, else delta).
    pub fn apply_comm(&self) {
        if let Some(m) = self.comm {
            crate::util::comm::set_comm_mode(m);
        }
    }

    /// Build the CLS problem instance this config describes.
    pub fn build_problem(&self) -> crate::cls::ClsProblem {
        use crate::domain::{generators, Mesh1d};
        let mesh = Mesh1d::new(self.n);
        let mut rng = crate::util::Rng::new(self.seed);
        let obs = generators::generate(self.layout, self.m, &mut rng);
        let y0 = (0..self.n)
            .map(|j| generators::field(j as f64 / (self.n - 1) as f64))
            .collect();
        crate::cls::ClsProblem::new(
            mesh,
            self.state_op.build(),
            y0,
            vec![self.state_weight; self.n],
            obs,
        )
    }

    /// Build the 2-D CLS problem instance a `dim = 2` config describes:
    /// an n × n grid, the 5-point analogue of the configured state
    /// operator, and observations of the configured 2-D layout.
    pub fn build_problem2d(&self) -> crate::cls::ClsProblem2d {
        use crate::domain2d::{generators as gen2d, Mesh2d};
        assert_eq!(self.dim, 2, "build_problem2d requires dim = 2");
        let mesh = Mesh2d::square(self.n);
        let mut rng = crate::util::Rng::new(self.seed);
        let obs = gen2d::generate(self.layout2d, self.m, &mut rng);
        let y0 = gen2d::background_field(&mesh);
        let state = self.state_op.build2d();
        let n = mesh.n();
        crate::cls::ClsProblem2d::new(mesh, state, y0, vec![self.state_weight; n], obs)
    }

    /// The coordinator RunConfig slice of this experiment.
    pub fn run_config(&self) -> crate::coordinator::RunConfig {
        crate::coordinator::RunConfig {
            schwarz: self.schwarz.clone(),
            backend: self.backend,
            artifacts_dir: self.artifacts_dir.clone(),
        }
    }

    /// The 1-D interval geometry (dim = 1) this config describes.
    pub fn interval_geometry(&self) -> IntervalGeometry {
        IntervalGeometry {
            mesh: crate::domain::Mesh1d::new(self.n),
            p: self.p,
            state: self.state_op.build(),
            state_weight: self.state_weight,
            layout: self.layout,
            drift: self.drift,
        }
    }

    /// The 2-D box-grid geometry (dim = 2) this config describes.
    pub fn box_geometry(&self) -> BoxGeometry {
        BoxGeometry {
            mesh: crate::domain2d::Mesh2d::square(self.n),
            px: self.px,
            py: self.py,
            state: self.state_op.build2d(),
            state_weight: self.state_weight,
            layout: self.layout2d,
            drift: self.drift2d,
        }
    }

    /// The 4-D space-time window geometry (dim = 4) this config describes:
    /// an `n`-point spatial mesh × `steps` time levels decomposed into `p`
    /// time windows, with the 1-D layout as the per-level spatial
    /// distribution and the 1-D drift moving the observation density over
    /// the time axis.
    pub fn window_geometry(&self) -> WindowGeometry {
        WindowGeometry {
            mesh: crate::domain::Mesh1d::new(self.n),
            steps: self.steps,
            windows: self.p,
            state: self.state_op.build(),
            state_weight: self.state_weight,
            model_weight: self.model_weight,
            layout: self.layout,
            drift: self.drift,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_from_toml() {
        let text = r#"
name = "table12"
[problem]
n = 512
m = 300
p = 8
layout = "ramp"
seed = 7
[schwarz]
overlap = 2
mu = 1e-6
[run]
backend = "native"
dydd = true
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.name, "table12");
        assert_eq!((cfg.n, cfg.m, cfg.p), (512, 300, 8));
        assert_eq!(cfg.layout, ObsLayout::Ramp);
        assert_eq!(cfg.schwarz.overlap, 2);
        assert_eq!(cfg.backend, SolverBackend::Native);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml_str("nonsense = 1").is_err());
    }

    #[test]
    fn cg_backend_parses_from_toml() {
        let cfg = ExperimentConfig::from_toml_str("[run]\nbackend = \"cg\"").unwrap();
        assert_eq!(cfg.backend, SolverBackend::Cg);
        assert!(ExperimentConfig::from_toml_str("[run]\nbackend = \"lobpcg\"").is_err());
    }

    #[test]
    fn perf_threads_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml_str("[perf]\nthreads = 4").unwrap();
        assert_eq!(cfg.threads, 4);
        // Default: inherit the process-wide setting.
        assert_eq!(ExperimentConfig::default().threads, 0);
        let mut bad = ExperimentConfig::default();
        bad.threads = 4096;
        assert!(bad.validate().is_err(), "absurd thread counts must be rejected");
    }

    #[test]
    fn perf_batch_parses_and_validates() {
        use crate::util::batch::BatchMode;
        let cfg = ExperimentConfig::from_toml_str("[perf]\nbatch = \"off\"").unwrap();
        assert_eq!(cfg.batch, Some(BatchMode::Off));
        let cfg = ExperimentConfig::from_toml_str("[perf]\nbatch = \"auto\"").unwrap();
        assert_eq!(cfg.batch, Some(BatchMode::Auto));
        // Default: inherit the process-wide setting.
        assert_eq!(ExperimentConfig::default().batch, None);
        assert!(
            ExperimentConfig::from_toml_str("[perf]\nbatch = \"sometimes\"").is_err(),
            "unknown batch modes must be rejected"
        );
    }

    #[test]
    fn perf_workers_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml_str("[perf]\nworkers = 8").unwrap();
        assert_eq!(cfg.workers, 8);
        // Default: inherit the process-wide setting (core-bounded auto).
        assert_eq!(ExperimentConfig::default().workers, 0);
        let mut bad = ExperimentConfig::default();
        bad.workers = 4096;
        assert!(bad.validate().is_err(), "absurd pool widths must be rejected");
    }

    #[test]
    fn perf_comm_parses_and_validates() {
        use crate::util::comm::CommMode;
        let cfg = ExperimentConfig::from_toml_str("[perf]\ncomm = \"full\"").unwrap();
        assert_eq!(cfg.comm, Some(CommMode::Full));
        let cfg = ExperimentConfig::from_toml_str("[perf]\ncomm = \"delta\"").unwrap();
        assert_eq!(cfg.comm, Some(CommMode::Delta));
        // Default: inherit the process-wide setting.
        assert_eq!(ExperimentConfig::default().comm, None);
        assert!(
            ExperimentConfig::from_toml_str("[perf]\ncomm = \"telepathy\"").is_err(),
            "unknown comm modes must be rejected"
        );
    }

    #[test]
    fn dim2_keys_roundtrip() {
        let text = r#"
name = "blob2d"
[problem]
dim = 2
n = 256
m = 2000
px = 4
py = 4
layout = "gaussian_blob"
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.dim, 2);
        assert_eq!((cfg.px, cfg.py), (4, 4));
        assert_eq!(cfg.layout2d, ObsLayout2d::GaussianBlob);
        // The 1-D layout stays at its default when a 2-D name is given.
        assert_eq!(cfg.layout, ObsLayout::Uniform);
    }

    #[test]
    fn wrong_dimension_layout_name_errors() {
        // A 1-D name under dim = 2 (and vice versa) must fail loudly, not
        // silently run the default layout.
        let err = ExperimentConfig::from_toml_str(
            "[problem]\ndim = 2\nlayout = \"cluster\"",
        )
        .unwrap_err();
        assert!(err.to_string().contains("not a 2-D layout"), "{err}");
        let err =
            ExperimentConfig::from_toml_str("[problem]\nlayout = \"ring\"").unwrap_err();
        assert!(err.to_string().contains("not a 1-D layout"), "{err}");
    }

    #[test]
    fn dim2_validation_catches_bad_grid() {
        let mut cfg = ExperimentConfig::default();
        cfg.dim = 2;
        cfg.px = 0;
        assert!(cfg.validate().is_err());
        cfg.px = 4;
        cfg.py = cfg.n; // absurd
        assert!(cfg.validate().is_err());
        cfg.py = 4;
        assert!(cfg.validate().is_ok());
        cfg.dim = 3;
        assert!(cfg.validate().is_err());
        // A small-n 2-D config must not be rejected over the unused 1-D p.
        let mut small = ExperimentConfig::default();
        small.dim = 2;
        small.n = 6;
        small.px = 2;
        small.py = 2;
        assert!(small.validate().is_ok(), "{:?}", small.validate());
    }

    #[test]
    fn dim4_keys_roundtrip_and_build_geometry() {
        let text = r#"
name = "pint"
[problem]
dim = 4
n = 12
steps = 16
p = 4
m = 320
layout = "cluster"
model_weight = 2.5
[cycle]
drift = "rotating_band"
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.dim, 4);
        assert_eq!((cfg.n, cfg.steps, cfg.p), (12, 16, 4));
        assert_eq!(cfg.model_weight, 2.5);
        // dim 4 resolves 1-D layout/drift names (spatial per level / time
        // axis respectively).
        assert_eq!(cfg.layout, ObsLayout::Cluster);
        assert_eq!(cfg.drift, DriftLayout::RotatingBand);
        let geom = cfg.window_geometry();
        assert_eq!(geom.steps, 16);
        assert_eq!(geom.windows, 4);
        assert_eq!(geom.model_weight, 2.5);
    }

    #[test]
    fn dim4_validation_catches_window_overflow() {
        let mut cfg = ExperimentConfig::default();
        cfg.dim = 4;
        cfg.n = 12;
        cfg.steps = 4;
        cfg.p = 8; // more windows than levels
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("time windows"), "{err}");
        cfg.p = 4;
        assert!(cfg.validate().is_ok());
        cfg.steps = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_p() {
        let mut cfg = ExperimentConfig::default();
        cfg.p = cfg.n; // too many subdomains
        assert!(cfg.validate().is_err());
        cfg.p = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_catches_oversized_overlap() {
        let mut cfg = ExperimentConfig::default();
        cfg.schwarz.overlap = cfg.n; // absurd
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn build_problem_matches_config() {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 128;
        cfg.m = 64;
        let prob = cfg.build_problem();
        assert_eq!(prob.n(), 128);
        assert_eq!(prob.m1(), 64);
    }

    #[test]
    fn build_problem2d_matches_config() {
        let mut cfg = ExperimentConfig::default();
        cfg.dim = 2;
        cfg.n = 24;
        cfg.m = 80;
        cfg.layout2d = ObsLayout2d::Ring;
        let prob = cfg.build_problem2d();
        assert_eq!(prob.n(), 24 * 24);
        assert_eq!(prob.m1(), 80);
        assert_eq!(prob.state, crate::cls::StateOp2d::FivePoint { main: 1.0, off: 0.15 });
    }

    #[test]
    fn cycle_section_roundtrips() {
        let text = r#"
name = "cycling"
[problem]
n = 512
m = 800
p = 4
[cycle]
count = 8
policy = "threshold"
tau = 0.85
drift = "translating_blob"
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.cycles, 8);
        assert_eq!(cfg.cycle_policy, RebalancePolicy::Threshold(0.85));
        assert_eq!(cfg.drift, DriftLayout::TranslatingBlob);
    }

    #[test]
    fn cycle_drift_is_dimension_sensitive() {
        let cfg = ExperimentConfig::from_toml_str(
            "[problem]\ndim = 2\n[cycle]\ndrift = \"rotating_band\"",
        )
        .unwrap();
        assert_eq!(cfg.drift2d, DriftLayout2d::RotatingBand);
        // 1-D default untouched when a 2-D drift name is set.
        assert_eq!(cfg.drift, DriftLayout::TranslatingBlob);
        let cfg =
            ExperimentConfig::from_toml_str("[cycle]\ndrift = \"stationary:cluster\"").unwrap();
        assert_eq!(cfg.drift, DriftLayout::Stationary(ObsLayout::Cluster));
        let err = ExperimentConfig::from_toml_str(
            "[problem]\ndim = 2\n[cycle]\ndrift = \"stationary:cluster\"",
        )
        .unwrap_err();
        assert!(err.to_string().contains("not a 2-D drift"), "{err}");
    }

    #[test]
    fn stream_section_roundtrips() {
        let text = r#"
name = "serving"
[problem]
n = 512
m = 800
p = 8
[stream]
ticks = 24
source = "replay"
feed_forward = false
warm_start = false
force_cold = true
"#;
        let cfg = ExperimentConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.ticks, 24);
        assert_eq!(cfg.stream_source, StreamSourceConfig::Replay);
        assert!(!cfg.stream_feed_forward);
        assert!(!cfg.stream_warm_start);
        assert!(cfg.stream_force_cold);
        // Defaults: native drift source, feed-forward warm serving.
        let d = ExperimentConfig::default();
        assert_eq!(d.stream_source, StreamSourceConfig::Drift);
        assert!(d.stream_feed_forward && d.stream_warm_start && !d.stream_force_cold);
        assert!(ExperimentConfig::from_toml_str("[stream]\nticks = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[stream]\nsource = \"carrier\"").is_err());
        assert_eq!(StreamSourceConfig::parse("-"), Some(StreamSourceConfig::Stdin));
    }

    #[test]
    fn cycle_section_rejects_bad_values() {
        assert!(ExperimentConfig::from_toml_str("[cycle]\ncount = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[cycle]\ntau = 1.5").is_err());
        // tau without a threshold policy is a configuration mistake.
        assert!(ExperimentConfig::from_toml_str(
            "[cycle]\npolicy = \"never\"\ntau = 0.5"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml_str("[cycle]\npolicy = \"sometimes\"").is_err());
        // threshold:τ inline form works too.
        let cfg =
            ExperimentConfig::from_toml_str("[cycle]\npolicy = \"threshold:0.7\"").unwrap();
        assert_eq!(cfg.cycle_policy, RebalancePolicy::Threshold(0.7));
    }

    #[test]
    fn dim2_overlap_validated_against_box_width() {
        let mut cfg = ExperimentConfig::default();
        cfg.dim = 2;
        cfg.n = 24;
        cfg.px = 4;
        cfg.py = 4;
        cfg.schwarz.overlap = 2;
        assert!(cfg.validate().is_ok());
        cfg.schwarz.overlap = 4; // > n / (2·max(px, py)) = 3
        assert!(cfg.validate().is_err());
    }
}
