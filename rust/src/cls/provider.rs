//! [`RowProvider`] — the one sparse-row contract every stacked
//! weighted-least-squares problem in the codebase satisfies.
//!
//! `ClsProblem` (1-D), `ClsProblem2d` (box grid) and
//! `fourd::TrajectoryProblem` (space-time) all describe the same object: a
//! stacked system A x = b with diagonal weights D, exposed row-by-row as
//! sparse `(col, coeff)` lists. This trait hosts the single implementation
//! of the dense materialization, the normal-equations reference solve and
//! the sparse optimality check the three problems used to triplicate, plus
//! the shared row-restriction core behind every `local_block` extraction.

use crate::linalg::mat::norm2;
use crate::linalg::{Cholesky, CsrMatrix, Mat};

/// One sparse row of the stacked system: (col, coeff) pairs (ascending
/// columns), the row's weight (inverse variance) and its datum.
pub type SparseRow = (Vec<(usize, f64)>, f64, f64);

/// A stacked weighted-least-squares system exposed as sparse rows.
pub trait RowProvider {
    /// Number of unknowns (columns of A).
    fn num_cols(&self) -> usize;

    /// Number of stacked rows (state/model rows first, then observations).
    fn num_rows(&self) -> usize;

    /// Sparse row r — see [`SparseRow`].
    fn provider_row(&self, r: usize) -> SparseRow;

    /// Problem family name used in diagnostics.
    fn kind(&self) -> &'static str {
        "CLS"
    }

    /// Dense (A, d, b) — reference/oracle paths only. Duplicate columns in
    /// a row accumulate, matching the CSR path's coalescing (so the oracle
    /// and the solve path can never disagree about such a row).
    fn dense(&self) -> (Mat, Vec<f64>, Vec<f64>) {
        let (m, n) = (self.num_rows(), self.num_cols());
        let mut a = Mat::zeros(m, n);
        let mut d = vec![0.0; m];
        let mut b = vec![0.0; m];
        for r in 0..m {
            let (cols, w, y) = self.provider_row(r);
            for (j, v) in cols {
                a[(r, j)] += v;
            }
            d[r] = w;
            b[r] = y;
        }
        (a, d, b)
    }

    /// Global normal-equations solution x̂ = (AᵀDA)⁻¹AᵀDb (eq. 19) — the
    /// reference every decomposed path is compared against. O(n³) dense;
    /// feasible on small problems only.
    fn solve_reference(&self) -> Vec<f64> {
        let (a, d, b) = self.dense();
        let g = a.weighted_gram(&d);
        let rhs = a.at_db(&d, &b);
        Cholesky::new(&g)
            // lint:allow(no-unwrap-in-lib) oracle path: non-SPD means a test-setup bug
            .unwrap_or_else(|e| panic!("{} normal matrix must be SPD: {e}", self.kind()))
            .solve(&rhs)
    }

    /// Relative normal-equations residual ‖AᵀD(b − Ax)‖ / ‖AᵀDb‖ computed
    /// in one sparse pass — a dense-free optimality check usable at scales
    /// where [`RowProvider::dense`] cannot be materialized.
    fn normal_residual(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.num_cols());
        let mut res = vec![0.0; self.num_cols()];
        let mut rhs = vec![0.0; self.num_cols()];
        for r in 0..self.num_rows() {
            let (cols, w, y) = self.provider_row(r);
            let mut ax = 0.0;
            for &(j, v) in &cols {
                ax += v * x[j];
            }
            for &(j, v) in &cols {
                res[j] += w * v * (y - ax);
                rhs[j] += w * v * y;
            }
        }
        norm2(&res) / norm2(&rhs).max(f64::MIN_POSITIVE)
    }
}

/// Restrict pre-fetched sparse rows to an explicit (strictly increasing)
/// column set: returns the local matrix in CSR form, weights, data, and
/// halo couplings for every coefficient at a column outside the set.
/// The shared core of every `local_block` extraction (1-D intervals, 2-D
/// boxes, 4-D time windows).
pub(crate) fn restrict_rows_cached(
    row_data: &[SparseRow],
    cols: &[usize],
) -> (CsrMatrix, Vec<f64>, Vec<f64>, Vec<(usize, usize, f64)>) {
    let m_loc = row_data.len();
    let mut rows_loc: Vec<Vec<(usize, f64)>> = Vec::with_capacity(m_loc);
    let mut d = vec![0.0; m_loc];
    let mut b = vec![0.0; m_loc];
    let mut halo: Vec<(usize, usize, f64)> = Vec::new();
    for (r_loc, (row, w, y)) in row_data.iter().enumerate() {
        d[r_loc] = *w;
        b[r_loc] = *y;
        let mut loc_row = Vec::with_capacity(row.len());
        for &(j, v) in row {
            if v == 0.0 {
                continue;
            }
            match cols.binary_search(&j) {
                Ok(c) => loc_row.push((c, v)),
                Err(_) => halo.push((r_loc, j, v)),
            }
        }
        rows_loc.push(loc_row);
    }
    (CsrMatrix::from_rows(cols.len(), &rows_loc), d, b, halo)
}

/// Restrict sparse rows (fetched through `sparse_row`) to an explicit
/// column set — see [`restrict_rows_cached`].
pub(crate) fn restrict_rows(
    rows: &[usize],
    cols: &[usize],
    sparse_row: impl Fn(usize) -> SparseRow,
) -> (CsrMatrix, Vec<f64>, Vec<f64>, Vec<(usize, usize, f64)>) {
    let row_data: Vec<SparseRow> = rows.iter().map(|&r| sparse_row(r)).collect();
    restrict_rows_cached(&row_data, cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::dist2;

    /// A toy provider: 3 unknowns, 4 rows.
    struct Toy;

    impl RowProvider for Toy {
        fn num_cols(&self) -> usize {
            3
        }

        fn num_rows(&self) -> usize {
            4
        }

        fn provider_row(&self, r: usize) -> SparseRow {
            match r {
                0 => (vec![(0, 1.0)], 2.0, 1.0),
                1 => (vec![(1, 1.0)], 2.0, 2.0),
                2 => (vec![(2, 1.0)], 2.0, 3.0),
                _ => (vec![(0, 1.0), (1, -1.0), (2, 0.5)], 4.0, 0.0),
            }
        }
    }

    #[test]
    fn dense_and_reference_agree_with_hand_solve() {
        let (a, d, b) = Toy.dense();
        assert_eq!((a.rows(), a.cols()), (4, 3));
        let x = Toy.solve_reference();
        let g = a.weighted_gram(&d);
        let rhs = a.at_db(&d, &b);
        assert!(dist2(&g.matvec(&x), &rhs) < 1e-12);
        // The minimizer has a (near-)zero sparse normal residual; a
        // perturbed point does not.
        assert!(Toy.normal_residual(&x) < 1e-12);
        let mut xp = x.clone();
        xp[0] += 0.1;
        assert!(Toy.normal_residual(&xp) > 1e-3);
    }

    #[test]
    fn restriction_splits_in_set_and_halo() {
        let cols = vec![0usize, 2];
        let rows = vec![0usize, 3];
        let (a, d, b, halo) = restrict_rows(&rows, &cols, |r| Toy.provider_row(r));
        assert_eq!(a.rows(), 2);
        assert_eq!(a.cols(), 2);
        assert_eq!(d, vec![2.0, 4.0]);
        assert_eq!(b, vec![1.0, 0.0]);
        assert_eq!(a.get(0, 0), 1.0);
        assert_eq!(a.get(1, 0), 1.0);
        assert_eq!(a.get(1, 1), 0.5);
        // Row 3's column-1 coefficient falls outside the set.
        assert_eq!(halo, vec![(1, 1, -1.0)]);
    }
}
