//! CLS problem assembly and local-block extraction (the DD-CLS restriction
//! A|_{I_i} of Definition 3 / eq. 23, exploiting row sparsity).

use super::provider::{restrict_rows, RowProvider, SparseRow};
use super::state_op::StateOp;
use crate::domain::{Mesh1d, ObservationSet, Partition};
use crate::linalg::{CsrMatrix, Mat};

/// A full CLS instance: state system (H0, y0, w0) + observations.
///
/// Weight convention: `w0[i]` and the observation weights are *inverse
/// variances* (the diagonal of R in the paper's ‖·‖²_R norms).
#[derive(Debug, Clone)]
pub struct ClsProblem {
    pub mesh: Mesh1d,
    pub state: StateOp,
    /// Background data y0 (length n).
    pub y0: Vec<f64>,
    /// State weights R0 diagonal (length n).
    pub w0: Vec<f64>,
    pub obs: ObservationSet,
}

impl ClsProblem {
    pub fn new(
        mesh: Mesh1d,
        state: StateOp,
        y0: Vec<f64>,
        w0: Vec<f64>,
        obs: ObservationSet,
    ) -> Self {
        assert_eq!(y0.len(), mesh.n());
        assert_eq!(w0.len(), mesh.n());
        assert!(w0.iter().all(|&w| w > 0.0), "state weights must be positive");
        ClsProblem { mesh, state, y0, w0, obs }
    }

    pub fn n(&self) -> usize {
        self.mesh.n()
    }

    /// m0: state rows (one per grid point).
    pub fn m0(&self) -> usize {
        self.mesh.n()
    }

    /// m1: observation rows.
    pub fn m1(&self) -> usize {
        self.obs.len()
    }

    pub fn m_total(&self) -> usize {
        self.m0() + self.m1()
    }

    /// Sparse row r of the stacked system A = [H0; H1] as (col, coef)
    /// pairs, plus its weight and datum.
    pub fn sparse_row(&self, r: usize) -> (Vec<(usize, f64)>, f64, f64) {
        let n = self.n();
        if r < n {
            (self.state.row(r, n), self.w0[r], self.y0[r])
        } else {
            let k = r - n;
            let (j, wl, wr) = self.obs.interp_row(&self.mesh, k);
            let row = if wr == 0.0 { vec![(j, wl)] } else { vec![(j, wl), (j + 1, wr)] };
            (row, 1.0 / self.obs.variances[k], self.obs.values[k])
        }
    }

    /// Dense (A, d, b) — reference/oracle paths only (shared
    /// [`RowProvider`] implementation).
    pub fn dense(&self) -> (Mat, Vec<f64>, Vec<f64>) {
        RowProvider::dense(self)
    }

    /// Global normal-equations solution x̂ = (AᵀRA)⁻¹AᵀRb (eq. 19) —
    /// the reference every decomposed path is compared against (shared
    /// [`RowProvider`] implementation).
    pub fn solve_reference(&self) -> Vec<f64> {
        RowProvider::solve_reference(self)
    }

    /// Extract the local block for subdomain `i` of `part`, extended by
    /// `overlap` columns into each neighbour (s of eqs. 21-22).
    ///
    /// Included rows: every row of A with at least one non-zero in the
    /// (extended) column interval. Coefficients at columns outside the
    /// interval become halo couplings (they multiply neighbour-owned
    /// unknowns in b_eff = b − A_other x_other, eq. 24).
    pub fn local_block(&self, part: &Partition, i: usize, overlap: usize) -> LocalBlock {
        let (lo, hi) = part.interval_with_overlap(i, overlap);
        let (own_lo, own_hi) = part.interval(i);
        let n = self.n();
        let bw = self.state.bandwidth();

        let mut rows: Vec<usize> = Vec::new();
        // State rows with support in [lo, hi): i ∈ [lo-bw, hi+bw) ∩ [0, n).
        let s_lo = lo.saturating_sub(bw);
        let s_hi = (hi + bw).min(n);
        rows.extend(s_lo..s_hi);
        let obs_row_start = rows.len();
        // Observation rows with interpolation support in [lo, hi).
        for k in 0..self.obs.len() {
            let (j, _, wr) = self.obs.interp_row(&self.mesh, k);
            let support_hi = if wr == 0.0 { j } else { j + 1 };
            if support_hi >= lo && j < hi {
                rows.push(n + k);
            }
        }

        let cols: Vec<usize> = (lo..hi).collect();
        let owned: Vec<bool> = cols.iter().map(|&c| (own_lo..own_hi).contains(&c)).collect();
        let (a, d, b, halo) = restrict_rows(&rows, &cols, |r| self.sparse_row(r));

        LocalBlock { cols, owned, a, d, b, halo, global_rows: rows, obs_row_start }
    }
}

impl RowProvider for ClsProblem {
    fn num_cols(&self) -> usize {
        self.n()
    }

    fn num_rows(&self) -> usize {
        self.m_total()
    }

    fn provider_row(&self, r: usize) -> SparseRow {
        self.sparse_row(r)
    }

    fn kind(&self) -> &'static str {
        "CLS"
    }
}

/// The restriction of a CLS system to one subdomain's columns.
///
/// The column set is an arbitrary strictly increasing list of global
/// indices — a contiguous interval in 1-D, the flattened halo-extended
/// rectangle of a [`crate::domain2d::BoxPartition`] box in 2-D. `owned`
/// marks the subdomain's own region; the rest is the overlap extension
/// into neighbours (eqs. 21-22).
#[derive(Debug, Clone)]
pub struct LocalBlock {
    /// Global column of each local column (strictly increasing).
    pub cols: Vec<usize>,
    /// owned[c]: local column c lies in the subdomain's own region (not
    /// in the overlap extension into a neighbour).
    pub owned: Vec<bool>,
    /// m_loc x n_loc restricted matrix A|_{I_i}, kept in CSR form so the
    /// problem-level sparsity survives all the way into the worker solve;
    /// dense consumers derive a [`Mat`] on demand via
    /// [`LocalBlock::dense_a`].
    pub a: CsrMatrix,
    /// Row weights (R diagonal).
    pub d: Vec<f64>,
    /// Row data b.
    pub b: Vec<f64>,
    /// Halo couplings: (local row, global column outside the column set,
    /// coefficient).
    pub halo: Vec<(usize, usize, f64)>,
    /// Global row index of each local row (diagnostics/tests).
    pub global_rows: Vec<usize>,
    /// Local row index where observation rows begin; state/model rows are
    /// always pushed first (row provenance for the KF local solver).
    pub obs_row_start: usize,
}

impl LocalBlock {
    pub fn n_loc(&self) -> usize {
        self.cols.len()
    }

    /// Local index of global column `gc`, if the block carries it.
    pub fn local_col(&self, gc: usize) -> Option<usize> {
        self.cols.binary_search(&gc).ok()
    }

    pub fn m_loc(&self) -> usize {
        self.a.rows()
    }

    /// Dense materialization of the restricted matrix — oracle paths and
    /// the artifact operand padding only; the native/CG solve paths stay
    /// on the CSR form.
    pub fn dense_a(&self) -> Mat {
        self.a.to_dense()
    }

    /// Distinct global columns referenced by halo couplings — the values a
    /// worker must receive from its neighbours each Schwarz iteration.
    pub fn halo_cols(&self) -> Vec<usize> {
        let mut cols: Vec<usize> = self.halo.iter().map(|&(_, c, _)| c).collect();
        cols.sort_unstable();
        cols.dedup();
        cols
    }

    /// b_eff = b − A_other x_other (eq. 24): subtract halo contributions
    /// given a lookup of neighbour-owned unknowns.
    pub fn b_eff(&self, x_at: impl Fn(usize) -> f64) -> Vec<f64> {
        let mut be = Vec::new();
        self.b_eff_into(x_at, &mut be);
        be
    }

    /// [`LocalBlock::b_eff`] into a reused buffer (cleared and refilled;
    /// the capacity survives across sweeps, so the per-iteration hot path
    /// allocates nothing).
    pub fn b_eff_into(&self, x_at: impl Fn(usize) -> f64, be: &mut Vec<f64>) {
        be.clear();
        be.extend_from_slice(&self.b);
        for &(r, c, v) in &self.halo {
            be[r] -= v * x_at(c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::generators::{self, ObsLayout};
    use crate::linalg::mat::dist2;
    use crate::util::Rng;

    pub fn small_problem(n: usize, m: usize, seed: u64) -> ClsProblem {
        let mesh = Mesh1d::new(n);
        let mut rng = Rng::new(seed);
        let obs = generators::generate(ObsLayout::Uniform, m, &mut rng);
        let y0: Vec<f64> = (0..n).map(|j| generators::field(j as f64 / (n - 1) as f64)).collect();
        let w0 = vec![4.0; n];
        ClsProblem::new(mesh, StateOp::Tridiag { main: 1.0, off: 0.15 }, y0, w0, obs)
    }

    #[test]
    fn dense_shapes() {
        let p = small_problem(32, 20, 1);
        let (a, d, b) = p.dense();
        assert_eq!(a.rows(), 52);
        assert_eq!(a.cols(), 32);
        assert_eq!(d.len(), 52);
        assert_eq!(b.len(), 52);
    }

    #[test]
    fn reference_solution_solves_normal_equations() {
        let p = small_problem(24, 16, 2);
        let x = p.solve_reference();
        let (a, d, b) = p.dense();
        let g = a.weighted_gram(&d);
        let rhs = a.at_db(&d, &b);
        assert!(dist2(&g.matvec(&x), &rhs) < 1e-9);
    }

    #[test]
    fn local_blocks_partition_all_rows_with_support() {
        let p = small_problem(40, 25, 3);
        let part = Partition::uniform(40, 4);
        let mut covered = vec![false; p.m_total()];
        for i in 0..4 {
            let blk = p.local_block(&part, i, 0);
            assert_eq!(blk.n_loc(), 10);
            for &r in &blk.global_rows {
                covered[r] = true;
            }
            // Every local row must have at least one non-zero in-block coef.
            for r_loc in 0..blk.m_loc() {
                let (cols, _) = blk.a.row(r_loc);
                assert!(!cols.is_empty(), "row {r_loc} of block {i} is all-zero");
            }
        }
        assert!(covered.iter().all(|&c| c), "some row belongs to no block");
    }

    #[test]
    fn halo_matches_dense_coupling() {
        // b_eff computed through halo couplings must equal the dense
        // b − A_other x_other.
        let p = small_problem(30, 18, 4);
        let part = Partition::uniform(30, 3);
        let (a, _d, b) = p.dense();
        let mut rng = Rng::new(5);
        let x_global = rng.gaussian_vec(30);
        for i in 0..3 {
            let blk = p.local_block(&part, i, 0);
            let be = blk.b_eff(|c| x_global[c]);
            for (r_loc, &r) in blk.global_rows.iter().enumerate() {
                let mut want = b[r];
                for c in 0..30 {
                    if blk.local_col(c).is_none() {
                        want -= a[(r, c)] * x_global[c];
                    }
                }
                assert!((be[r_loc] - want).abs() < 1e-12, "block {i} row {r_loc}");
            }
        }
    }

    #[test]
    fn overlap_extends_columns() {
        let p = small_problem(30, 10, 6);
        let part = Partition::uniform(30, 3);
        let blk = p.local_block(&part, 1, 2);
        assert_eq!(blk.cols, (8..22).collect::<Vec<_>>());
        // Owned region [10, 20); the 2-column extensions are not owned.
        let owned: Vec<usize> =
            (0..blk.n_loc()).filter(|&c| blk.owned[c]).map(|c| blk.cols[c]).collect();
        assert_eq!(owned, (10..20).collect::<Vec<_>>());
        // State rows come first; obs rows follow.
        assert!(blk.global_rows[..blk.obs_row_start].iter().all(|&r| r < 30));
        assert!(blk.global_rows[blk.obs_row_start..].iter().all(|&r| r >= 30));
    }

    #[test]
    fn halo_cols_only_near_boundaries() {
        let p = small_problem(64, 30, 7);
        let part = Partition::uniform(64, 4);
        let blk = p.local_block(&part, 1, 0);
        // Interval [16, 32); tridiag bw 1 + interp support 1 => halo cols
        // within 2 of the boundary.
        for c in blk.halo_cols() {
            assert!(
                (14..16).contains(&c) || (32..34).contains(&c),
                "unexpected halo col {c}"
            );
        }
    }
}
