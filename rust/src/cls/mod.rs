//! The Constrained Least Squares model — the paper's prototype DA problem
//! (§3.1): two stacked weighted overdetermined systems
//!
//! ```text
//!   H0 x = y0   (state / background,  m0 x n)
//!   H1 x = y1   (observations,        m1 x n)
//! ```
//!
//! with weights R = diag(R0, R1) and solution
//! x̂ = (AᵀRA)⁻¹ AᵀRb (eqs. 18-19).
//!
//! Every problem family (1-D, 2-D box grid, 4-D trajectory) exposes its
//! rows through the shared [`RowProvider`] sparse-row contract, and local
//! blocks keep the restricted rows in CSR form ([`LocalBlock::a`]) so the
//! sparsity survives from problem definition to worker solve.

mod problem;
mod problem2d;
pub(crate) mod provider;
mod state_op;

pub use problem::{ClsProblem, LocalBlock};
pub use problem2d::ClsProblem2d;
pub use provider::{RowProvider, SparseRow};
pub use state_op::{StateOp, StateOp2d};
