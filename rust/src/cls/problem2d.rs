//! 2-D CLS problem assembly and box-local-block extraction — the DD-CLS
//! restriction of Definition 3 / eq. 23 on a tensor-product grid, with
//! bilinear-interpolation observation rows and a 5-point Laplacian
//! smoothness block (the overlapping restriction/extension operators of
//! the space-time DD-KF line of work, arXiv:2312.00007 / 1807.07103).

use super::problem::LocalBlock;
use super::provider::{restrict_rows, RowProvider, SparseRow};
use super::state_op::StateOp2d;
use crate::domain2d::{BoxPartition, Mesh2d, ObservationSet2d};
use crate::linalg::Mat;

/// A full 2-D CLS instance: state system (H0, y0, w0) on the flattened
/// `nx × ny` grid plus point observations with bilinear operator rows.
///
/// Weight convention matches [`super::ClsProblem`]: `w0` and the
/// observation weights are inverse variances.
#[derive(Debug, Clone)]
pub struct ClsProblem2d {
    pub mesh: Mesh2d,
    pub state: StateOp2d,
    /// Background data y0 (length nx·ny, row-major).
    pub y0: Vec<f64>,
    /// State weights R0 diagonal (length nx·ny).
    pub w0: Vec<f64>,
    pub obs: ObservationSet2d,
}

impl ClsProblem2d {
    pub fn new(
        mesh: Mesh2d,
        state: StateOp2d,
        y0: Vec<f64>,
        w0: Vec<f64>,
        obs: ObservationSet2d,
    ) -> Self {
        assert_eq!(y0.len(), mesh.n());
        assert_eq!(w0.len(), mesh.n());
        assert!(w0.iter().all(|&w| w > 0.0), "state weights must be positive");
        ClsProblem2d { mesh, state, y0, w0, obs }
    }

    /// Flattened unknown dimension nx·ny.
    pub fn n(&self) -> usize {
        self.mesh.n()
    }

    /// m0: state rows (one per grid point).
    pub fn m0(&self) -> usize {
        self.mesh.n()
    }

    /// m1: observation rows.
    pub fn m1(&self) -> usize {
        self.obs.len()
    }

    pub fn m_total(&self) -> usize {
        self.m0() + self.m1()
    }

    /// Sparse row r of the stacked system A = [H0; H1] as (col, coef)
    /// pairs (ascending columns, zero bilinear weights dropped), plus its
    /// weight and datum.
    pub fn sparse_row(&self, r: usize) -> (Vec<(usize, f64)>, f64, f64) {
        let n = self.n();
        if r < n {
            let (ix, iy) = self.mesh.unindex(r);
            (self.state.row(ix, iy, &self.mesh), self.w0[r], self.y0[r])
        } else {
            let k = r - n;
            let row: Vec<(usize, f64)> = self
                .obs
                .interp_row(&self.mesh, k)
                .into_iter()
                .filter(|&(_, w)| w != 0.0)
                .collect();
            (row, 1.0 / self.obs.variances[k], self.obs.values[k])
        }
    }

    /// Dense (A, d, b) — reference/oracle paths only (shared
    /// [`RowProvider`] implementation).
    pub fn dense(&self) -> (Mat, Vec<f64>, Vec<f64>) {
        RowProvider::dense(self)
    }

    /// Global normal-equations solution (eq. 19) — the reference every
    /// decomposed 2-D path is compared against. O(n³) dense; small grids
    /// (shared [`RowProvider`] implementation).
    pub fn solve_reference(&self) -> Vec<f64> {
        RowProvider::solve_reference(self)
    }

    /// Extract the local block of box `b` of `part`, extended by an
    /// `overlap` halo on every side (eqs. 21-22 per axis).
    ///
    /// Included rows: state rows whose stencil support intersects the
    /// extended rectangle (the cross-shaped expansion by the stencil
    /// bandwidth — corner-diagonal points carry no 5-point support) and
    /// observation rows with at least one non-zero bilinear weight inside.
    /// Out-of-rectangle coefficients become halo couplings for
    /// b_eff = b − A_other·x_other (eq. 24).
    pub fn local_block(&self, part: &BoxPartition, b: usize, overlap: usize) -> LocalBlock {
        let ext = part.rect_with_overlap(b, overlap);
        let own = part.rect(b);
        let (nx, ny) = (self.mesh.nx(), self.mesh.ny());
        let n = self.n();

        let mut cols = Vec::with_capacity((ext.x1 - ext.x0) * (ext.y1 - ext.y0));
        let mut owned = Vec::with_capacity(cols.capacity());
        for iy in ext.y0..ext.y1 {
            for ix in ext.x0..ext.x1 {
                cols.push(self.mesh.index(ix, iy));
                owned.push(own.contains(ix, iy));
            }
        }

        // State rows: cross-shaped expansion of the rectangle by the
        // stencil bandwidth (ascending flattened ids: outer loop is iy).
        let bw = self.state.bandwidth();
        let mut rows: Vec<usize> = Vec::new();
        for iy in ext.y0.saturating_sub(bw)..(ext.y1 + bw).min(ny) {
            let (xa, xb) = if (ext.y0..ext.y1).contains(&iy) {
                (ext.x0.saturating_sub(bw), (ext.x1 + bw).min(nx))
            } else {
                (ext.x0, ext.x1)
            };
            for ix in xa..xb {
                rows.push(self.mesh.index(ix, iy));
            }
        }
        let obs_row_start = rows.len();
        for k in 0..self.obs.len() {
            let support = self.obs.interp_row(&self.mesh, k);
            if support.iter().any(|&(j, w)| {
                let (ix, iy) = self.mesh.unindex(j);
                w != 0.0 && ext.contains(ix, iy)
            }) {
                rows.push(n + k);
            }
        }

        let (a, d, bb, halo) = restrict_rows(&rows, &cols, |r| self.sparse_row(r));
        LocalBlock { cols, owned, a, d, b: bb, halo, global_rows: rows, obs_row_start }
    }
}

impl RowProvider for ClsProblem2d {
    fn num_cols(&self) -> usize {
        self.n()
    }

    fn num_rows(&self) -> usize {
        self.m_total()
    }

    fn provider_row(&self, r: usize) -> SparseRow {
        self.sparse_row(r)
    }

    fn kind(&self) -> &'static str {
        "2-D CLS"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain2d::generators::{self, ObsLayout2d};
    use crate::linalg::mat::dist2;
    use crate::util::Rng;

    pub fn small_problem2d(n: usize, m: usize, seed: u64) -> ClsProblem2d {
        let mesh = Mesh2d::square(n);
        let mut rng = Rng::new(seed);
        let obs = generators::generate(ObsLayout2d::Uniform2d, m, &mut rng);
        let y0 = generators::background_field(&mesh);
        let w0 = vec![4.0; mesh.n()];
        ClsProblem2d::new(mesh, StateOp2d::FivePoint { main: 1.0, off: 0.12 }, y0, w0, obs)
    }

    #[test]
    fn dense_shapes_and_reference() {
        let p = small_problem2d(8, 20, 1);
        let (a, d, b) = p.dense();
        assert_eq!(a.rows(), 64 + 20);
        assert_eq!(a.cols(), 64);
        assert_eq!(d.len(), 84);
        assert_eq!(b.len(), 84);
        let x = p.solve_reference();
        let g = a.weighted_gram(&d);
        let rhs = a.at_db(&d, &b);
        assert!(dist2(&g.matvec(&x), &rhs) < 1e-9);
    }

    #[test]
    fn local_blocks_cover_all_rows_with_support() {
        let p = small_problem2d(12, 30, 2);
        let part = BoxPartition::uniform(12, 12, 2, 2);
        let mut covered = vec![false; p.m_total()];
        for b in 0..4 {
            let blk = p.local_block(&part, b, 0);
            assert_eq!(blk.n_loc(), 36);
            assert_eq!(blk.owned.iter().filter(|&&o| o).count(), 36);
            for &r in &blk.global_rows {
                covered[r] = true;
            }
            // Every local row has at least one non-zero in-block coef.
            for r_loc in 0..blk.m_loc() {
                let (cols, _) = blk.a.row(r_loc);
                assert!(!cols.is_empty(), "row {r_loc} of block {b} is all-zero");
            }
            // Provenance split: state rows first, obs rows after.
            assert!(blk.global_rows[..blk.obs_row_start].iter().all(|&r| r < p.n()));
            assert!(blk.global_rows[blk.obs_row_start..].iter().all(|&r| r >= p.n()));
        }
        assert!(covered.iter().all(|&c| c), "some row belongs to no block");
    }

    #[test]
    fn halo_matches_dense_coupling() {
        let p = small_problem2d(10, 25, 3);
        let part = BoxPartition::uniform(10, 10, 2, 2);
        let (a, _d, b) = p.dense();
        let mut rng = Rng::new(5);
        let x_global = rng.gaussian_vec(100);
        for bx in 0..4 {
            let blk = p.local_block(&part, bx, 1);
            let be = blk.b_eff(|c| x_global[c]);
            for (r_loc, &r) in blk.global_rows.iter().enumerate() {
                let mut want = b[r];
                for c in 0..100 {
                    if blk.local_col(c).is_none() {
                        want -= a[(r, c)] * x_global[c];
                    }
                }
                assert!((be[r_loc] - want).abs() < 1e-12, "box {bx} row {r_loc}");
            }
        }
    }

    #[test]
    fn overlap_extends_rectangle() {
        let p = small_problem2d(12, 10, 4);
        let part = BoxPartition::uniform(12, 12, 2, 2);
        // Interior corner box (1, 1) extended by 2 into both neighbours.
        let blk = p.local_block(&part, part.box_id(1, 1), 2);
        assert_eq!(blk.n_loc(), 8 * 8);
        let n_owned = blk.owned.iter().filter(|&&o| o).count();
        assert_eq!(n_owned, 36);
        // Non-owned columns are exactly the halo ring inside [4, 12)².
        for (c, &gc) in blk.cols.iter().enumerate() {
            let (ix, iy) = p.mesh.unindex(gc);
            assert_eq!(blk.owned[c], ix >= 6 && iy >= 6, "({ix},{iy})");
            assert!(ix >= 4 && iy >= 4);
        }
    }

    #[test]
    fn blocks_reconstruct_global_gram_diagonal() {
        // Zero overlap: summing every block's AᵀDA scattered to global
        // indices reproduces the global normal matrix on owned pairs.
        let p = small_problem2d(10, 22, 6);
        let part = BoxPartition::uniform(10, 10, 2, 2);
        let (a, d, _) = p.dense();
        let g_global = a.weighted_gram(&d);
        for b in 0..4 {
            let blk = p.local_block(&part, b, 0);
            let g_loc = blk.a.weighted_gram(&blk.d);
            for r in 0..blk.n_loc() {
                for c in 0..blk.n_loc() {
                    let diff = (g_global[(blk.cols[r], blk.cols[c])] - g_loc[(r, c)]).abs();
                    assert!(diff < 1e-10, "box {b} ({r},{c}): {diff}");
                }
            }
        }
    }
}
