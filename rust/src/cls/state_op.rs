//! The state operator H0 — the discretized dynamic-model constraint of the
//! CLS formulation.
//!
//! The paper treats H0 abstractly ("rewrite the state estimation problem
//! as a CLS model"); we provide the structured operators a discretize-
//! then-optimize pipeline actually produces, with explicit sparse row
//! access so local blocks can be extracted without densifying.

use crate::linalg::Mat;

/// Structured n x n state operators.
#[derive(Debug, Clone, PartialEq)]
pub enum StateOp {
    /// H0 = I: pure background term (3D-Var-like).
    Identity,
    /// Tridiagonal smoothing/transport stencil: row i is
    /// (off, main, off) at columns (i-1, i, i+1) — the discretization of a
    /// 1-D diffusion/advection model constraint. Boundary rows truncate.
    Tridiag { main: f64, off: f64 },
}

impl StateOp {
    /// Non-zero entries (col, val) of row i, ascending by column.
    pub fn row(&self, i: usize, n: usize) -> Vec<(usize, f64)> {
        debug_assert!(i < n);
        match *self {
            StateOp::Identity => vec![(i, 1.0)],
            StateOp::Tridiag { main, off } => {
                let mut r = Vec::with_capacity(3);
                if i > 0 {
                    r.push((i - 1, off));
                }
                r.push((i, main));
                if i + 1 < n {
                    r.push((i + 1, off));
                }
                r
            }
        }
    }

    /// Column support half-width: rows within this distance of a column
    /// interval can touch it.
    pub fn bandwidth(&self) -> usize {
        match self {
            StateOp::Identity => 0,
            StateOp::Tridiag { .. } => 1,
        }
    }

    /// Dense n x n materialization (reference/oracle paths only).
    pub fn to_dense(&self, n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for (j, v) in self.row(i, n) {
                m[(i, j)] = v;
            }
        }
        m
    }

    /// y = H0 x without materializing.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let n = x.len();
        (0..n)
            .map(|i| self.row(i, n).into_iter().map(|(j, v)| v * x[j]).sum())
            .collect()
    }
}

/// Structured 2-D state operators on a tensor-product [`Mesh2d`] — the
/// dimension-2 analogue of [`StateOp`] over the flattened (row-major)
/// unknown vector.
#[derive(Debug, Clone, PartialEq)]
pub enum StateOp2d {
    /// H0 = I: pure background term.
    Identity,
    /// 5-point Laplacian smoothness stencil: row (ix, iy) carries `main`
    /// at the centre and `off` at the 4 axis neighbours (truncated at the
    /// boundary) — the discretization of a 2-D diffusion constraint and
    /// the tensor generalization of [`StateOp::Tridiag`].
    FivePoint { main: f64, off: f64 },
}

use crate::domain2d::Mesh2d;

impl StateOp2d {
    /// Non-zero entries (flattened col, val) of the row at grid point
    /// (ix, iy), ascending by column.
    pub fn row(&self, ix: usize, iy: usize, mesh: &Mesh2d) -> Vec<(usize, f64)> {
        debug_assert!(ix < mesh.nx() && iy < mesh.ny());
        match *self {
            StateOp2d::Identity => vec![(mesh.index(ix, iy), 1.0)],
            StateOp2d::FivePoint { main, off } => {
                let mut r = Vec::with_capacity(5);
                if iy > 0 {
                    r.push((mesh.index(ix, iy - 1), off));
                }
                if ix > 0 {
                    r.push((mesh.index(ix - 1, iy), off));
                }
                r.push((mesh.index(ix, iy), main));
                if ix + 1 < mesh.nx() {
                    r.push((mesh.index(ix + 1, iy), off));
                }
                if iy + 1 < mesh.ny() {
                    r.push((mesh.index(ix, iy + 1), off));
                }
                r
            }
        }
    }

    /// Stencil half-width along each axis (the cross-shaped support used
    /// by local-block row selection).
    pub fn bandwidth(&self) -> usize {
        match self {
            StateOp2d::Identity => 0,
            StateOp2d::FivePoint { .. } => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::dist2;
    use crate::util::Rng;

    #[test]
    fn identity_rows() {
        let op = StateOp::Identity;
        assert_eq!(op.row(3, 8), vec![(3, 1.0)]);
        assert_eq!(op.bandwidth(), 0);
    }

    #[test]
    fn tridiag_truncates_at_boundaries() {
        let op = StateOp::Tridiag { main: 2.0, off: -0.5 };
        assert_eq!(op.row(0, 4), vec![(0, 2.0), (1, -0.5)]);
        assert_eq!(op.row(3, 4), vec![(2, -0.5), (3, 2.0)]);
        assert_eq!(op.row(1, 4), vec![(0, -0.5), (1, 2.0), (2, -0.5)]);
    }

    #[test]
    fn matvec_matches_dense() {
        let op = StateOp::Tridiag { main: 1.5, off: 0.25 };
        let mut rng = Rng::new(1);
        let x = rng.gaussian_vec(16);
        let want = op.to_dense(16).matvec(&x);
        assert!(dist2(&op.matvec(&x), &want) < 1e-14);
    }

    #[test]
    fn five_point_truncates_at_boundaries() {
        let mesh = Mesh2d::new(4, 3);
        let op = StateOp2d::FivePoint { main: 4.0, off: -1.0 };
        // Interior point (1, 1) = flat 5: full 5-point cross.
        assert_eq!(
            op.row(1, 1, &mesh),
            vec![(1, -1.0), (4, -1.0), (5, 4.0), (6, -1.0), (9, -1.0)]
        );
        // Corner (0, 0): only right + up neighbours survive.
        assert_eq!(op.row(0, 0, &mesh), vec![(0, 4.0), (1, -1.0), (4, -1.0)]);
        // Columns are strictly ascending for every grid point.
        for iy in 0..3 {
            for ix in 0..4 {
                let r = op.row(ix, iy, &mesh);
                assert!(r.windows(2).all(|w| w[0].0 < w[1].0), "({ix},{iy})");
            }
        }
        assert_eq!(op.bandwidth(), 1);
        assert_eq!(StateOp2d::Identity.row(2, 1, &mesh), vec![(6, 1.0)]);
    }
}
