//! 1-D advection–diffusion propagator on a periodic domain:
//!
//!   u_t + c u_x = ν u_xx
//!
//! discretized with Lax–Wendroff advection + explicit central diffusion.
//! The resulting tridiagonal-circulant matrix is the dynamic model M of
//! the e2e driver; stability (CFL + diffusion number) is checked at
//! construction.

use super::DynamicModel;
use crate::linalg::Mat;

/// Periodic 1-D advection–diffusion model.
#[derive(Debug, Clone)]
pub struct AdvectionDiffusion {
    n: usize,
    pub courant: f64,
    pub diffusion_number: f64,
    m: Mat,
}

/// Build the propagator for grid size `n`, velocity `c`, viscosity `nu`,
/// time step `dt` (grid spacing h = 1/n, periodic).
pub fn advection_diffusion(n: usize, c: f64, nu: f64, dt: f64) -> AdvectionDiffusion {
    assert!(n >= 4);
    let h = 1.0 / n as f64;
    let courant = c * dt / h;
    let diffusion_number = nu * dt / (h * h);
    assert!(
        courant.abs() <= 1.0,
        "CFL violated: |c dt / h| = {courant} > 1"
    );
    assert!(
        diffusion_number <= 0.5,
        "diffusion number {diffusion_number} > 0.5 (explicit scheme unstable)"
    );
    // Lax–Wendroff: u_i' = u_i − C/2 (u_{i+1} − u_{i−1}) + C²/2 (u_{i+1} − 2u_i + u_{i−1})
    // plus diffusion D (u_{i+1} − 2u_i + u_{i−1}).
    let cc = courant;
    let dd = diffusion_number;
    let lower = cc / 2.0 + cc * cc / 2.0 + dd; // coefficient of u_{i−1}
    let diag = 1.0 - cc * cc - 2.0 * dd;
    let upper = -cc / 2.0 + cc * cc / 2.0 + dd;
    let mut m = Mat::zeros(n, n);
    for i in 0..n {
        m[(i, (i + n - 1) % n)] = lower;
        m[(i, i)] = diag;
        m[(i, (i + 1) % n)] = upper;
    }
    AdvectionDiffusion { n, courant, diffusion_number, m }
}

impl DynamicModel for AdvectionDiffusion {
    fn n(&self) -> usize {
        self.n
    }

    fn matrix(&self) -> &Mat {
        &self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conserves_mass() {
        // Row... column sums of M must be 1 (sum_i u_i' = sum_i u_i for
        // periodic conservative stencils): each column's coefficients are
        // (upper, diag, lower) which sum to 1.
        let model = advection_diffusion(64, 1.0, 1e-3, 0.005);
        let m = model.matrix();
        for j in 0..64 {
            let s: f64 = (0..64).map(|i| m[(i, j)]).sum();
            assert!((s - 1.0).abs() < 1e-12, "col {j}: {s}");
        }
    }

    #[test]
    fn transports_a_bump() {
        let n = 128;
        let model = advection_diffusion(n, 1.0, 0.0, 1.0 / n as f64); // C = 1: exact shift
        let mut u = vec![0.0; n];
        u[10] = 1.0;
        let u1 = model.step(&u);
        // With Courant number exactly 1 Lax–Wendroff shifts by one cell.
        assert!((u1[11] - 1.0).abs() < 1e-12, "{:?}", &u1[8..14]);
    }

    #[test]
    fn diffusion_smooths() {
        let n = 64;
        let model = advection_diffusion(n, 0.0, 1e-3, 0.01);
        let mut u = vec![0.0; n];
        u[32] = 1.0;
        let u1 = model.step(&u);
        assert!(u1[32] < 1.0);
        assert!(u1[31] > 0.0 && u1[33] > 0.0);
        // Mass conserved.
        assert!((u1.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "CFL")]
    fn cfl_checked() {
        advection_diffusion(64, 10.0, 0.0, 0.1);
    }

    #[test]
    #[should_panic(expected = "diffusion number")]
    fn diffusion_stability_checked() {
        advection_diffusion(64, 0.0, 1.0, 0.01);
    }
}
