//! Dynamic models M_{k,k+1} for the e2e assimilation driver (the paper's
//! eq. 1 discretized to a linear propagator matrix).

mod advection;

pub use advection::{advection_diffusion, AdvectionDiffusion};

use crate::linalg::Mat;

/// A linear dynamic model: x_{k+1} = M x_k (+ w_k).
pub trait DynamicModel {
    fn n(&self) -> usize;
    /// The propagator matrix M_{k,k+1} (time-invariant here).
    fn matrix(&self) -> &Mat;
    /// Apply without materializing products elsewhere.
    fn step(&self, x: &[f64]) -> Vec<f64> {
        self.matrix().matvec(x)
    }
}
