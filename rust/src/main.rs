//! `dydd-da` — CLI launcher for the DyDD / DD-KF framework.
//!
//! Subcommands:
//!   info                     platform, artifact and build information
//!   run [--config F] [...]   run one experiment (DyDD + DD-KF + baseline;
//!                            --dim 2 runs the full pipeline on a px × py
//!                            box grid over [0,1]²; --dim 4 on space-time
//!                            windows of an n × steps trajectory)
//!   cycle [...]              multi-cycle assimilation with drifting
//!                            observations and a DyDD rebalance policy
//!                            (any dim, including 4-D space-time windows)
//!   serve [...]              streaming incremental assimilation: ingest
//!                            per-tick observation deltas (native drift
//!                            stream or JSONL stdin), re-solve only dirty
//!                            blocks, emit per-tick JSONL telemetry
//!   dydd --loads a,b,c ...   run the load balancer on an abstract scenario
//!   dydd --dim 2 [...]       geometric DyDD on a px × py box grid
//!   table <1..12|fig5|all>   regenerate the paper's tables/figures
//!   bench-tables [--full]    regenerate everything (what EXPERIMENTS.md cites)

use dydd_da::config::{ExperimentConfig, StreamSourceConfig};
use dydd_da::coordinator::SolverBackend;
use dydd_da::decomp::registry::{self, DriftSpec, LayoutSpec};
use dydd_da::decomp::{BoxGeometry, RecordGeometry};
use dydd_da::dydd::{balance, balance_ratio, rebalance, DyddParams, RebalancePolicy};
use dydd_da::graph::Graph;
use dydd_da::harness::cycles::render_cycle_table;
use dydd_da::harness::{
    all_tables, render_table, run_cycles, run_experiment, scenarios, ExperimentReport, TableId,
};
use dydd_da::runtime;
use dydd_da::stream::{
    run_stream, DriftSource, JsonlSource, ReplaySource, StreamOptions, StreamReport,
};
use dydd_da::util::timer::fmt_secs;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("info") => cmd_info(),
        Some("run") => cmd_run(&args[1..]),
        Some("cycle") => cmd_cycle(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("dydd") => cmd_dydd(&args[1..]),
        Some("table") => cmd_table(&args[1..]),
        Some("bench-tables") => cmd_bench_tables(&args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(anyhow::anyhow!("unknown subcommand {other:?}\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
dydd-da — Parallel Dynamic Domain Decomposition for Data Assimilation

USAGE:
  dydd-da info
  dydd-da run [--config FILE] [--n N] [--m M] [--p P] [--layout L]
              [--dim 1|2|4] [--px PX] [--py PY] [--steps N_T]
              [--backend native|kf|pjrt|cg|cg-ic0] [--overlap S] [--mu MU]
              [--threads T] [--batch on|off|auto] [--workers W]
              [--comm full|restricted|delta] [--no-dydd] [--seed SEED]
              [--no-baseline]
  dydd-da cycle [--config FILE] [--dim 1|2|4] [--n N] [--m M] [--p P]
              [--px PX] [--py PY] [--steps N_T] [--cycles K] [--backend B]
              [--policy never|every_cycle|threshold[:TAU]] [--tau TAU]
              [--drift D] [--seed SEED] [--threads T] [--batch on|off|auto]
              [--workers W] [--comm full|restricted|delta] [--no-dydd]
              [--no-baseline]
  dydd-da serve [--config FILE] [--dim 1|2|4] [--n N] [--m M] [--p P]
              [--px PX] [--py PY] [--steps N_T] [--ticks K] [--backend B]
              [--policy never|every_cycle|threshold[:TAU]] [--tau TAU]
              [--drift D] [--seed SEED] [--source drift|replay|-]
              [--threads T] [--batch on|off|auto] [--workers W]
              [--comm full|restricted|delta] [--no-dydd]
              [--no-baseline] [--no-feed-forward] [--no-warm-start]
              [--force-cold]
  dydd-da dydd --loads L1,L2,... [--graph chain|star|ring]
  dydd-da dydd --dim 2 [--px PX] [--py PY] [--layout L2] [--n N] [--m M]
              [--seed SEED]
  dydd-da table <1..12|fig5|all> [--full]
  dydd-da bench-tables [--full]

1-D layouts: uniform | ramp | cluster | two_clusters | left_packed
2-D layouts: uniform2d | gaussian_blob | diagonal_band | ring | quadrant
drifts (1-D and 2-D): translating_blob | rotating_band | appearing_cluster
                      | stationary:<layout>
dim 4 (space-time): p = time windows over an n x steps trajectory; 1-D
                    layouts give the per-level spatial distribution and
                    1-D drifts move the density over the time axis
backends: native (Cholesky) | kf (local VAR-KF) | pjrt (XLA artifacts)
          | cg (sparse matrix-free PCG — use for large grids, e.g.
          `run --dim 2 --n 128 --backend cg`) | cg-ic0 (same PCG with a
          blocked IC(0) preconditioner — fewer iterations on
          stencil-coupled blocks)
--threads T: dense/sparse kernel threads (default: DYDD_THREADS or 1).
          Banded deterministic reduction — results are bitwise-identical
          at every thread count.
--batch M: same-shape block dispatch (default: DYDD_BATCH or auto). on =
          always group same-shape blocks into fused batched solves, off =
          per-block dispatch, auto = group only where batching wins.
          Batched dispatch is bitwise-identical to per-block.
--workers W: coordinator pool width — how many worker threads host the p
          subdomain blocks (default: DYDD_WORKERS, else min(p, cores)).
          Results are bitwise-identical at every W; --threads parallelizes
          kernels inside one solve, --workers schedules solves themselves.
--comm M: leader-to-worker iterate exchange (default: DYDD_COMM or delta).
          full = dense broadcast of the whole iterate every phase,
          restricted = each block's recorded read set only, delta = read
          set once, then only changed entries (+ skipped sends for
          unchanged pure-solver blocks). All modes are bitwise-identical.
serve sources: drift (native per-row stream; falls back to replay when
          the geometry has none) | replay (per-tick cycle_obs diffs)
          | - (JSONL deltas on stdin, one {tick, add, remove, move}
          object per line); telemetry goes to stdout as JSONL
";

/// The sequential-KF baseline keeps a dense n × n covariance and pays
/// O(n²) per observation; past this many unknowns it is skipped (the CG
/// backend exists precisely for problems that big).
const MAX_BASELINE_UNKNOWNS: usize = 10_000;

/// Decide whether the T¹ baseline runs: the user's `--no-baseline` wins,
/// then the dense-feasibility cutoff (with a loud note so a silently
/// missing error_DD-DA column is never a mystery).
fn baseline_enabled(no_baseline_flag: bool, unknowns: usize) -> bool {
    if no_baseline_flag {
        return false;
    }
    if unknowns > MAX_BASELINE_UNKNOWNS {
        eprintln!(
            "note: {unknowns} unknowns exceeds the dense sequential-KF baseline budget \
             ({MAX_BASELINE_UNKNOWNS}); skipping T¹/error_DD-DA (pass --n small enough, \
             or trust the Schwarz convergence report)"
        );
        return false;
    }
    true
}

/// Tiny flag parser: `--key value` and boolean `--flag`.
struct Flags<'a> {
    args: &'a [String],
}

impl<'a> Flags<'a> {
    fn get(&self, key: &str) -> Option<&'a str> {
        self.args
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.args.get(i + 1))
            .map(String::as_str)
    }

    fn has(&self, key: &str) -> bool {
        self.args.iter().any(|a| a == key)
    }

    fn parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("bad value for {key}: {v:?}")),
        }
    }

    /// The `--batch on|off|auto` flag, shared by run/cycle/serve.
    fn batch(&self) -> anyhow::Result<Option<dydd_da::util::batch::BatchMode>> {
        match self.get("--batch") {
            None => Ok(None),
            Some(s) => dydd_da::util::batch::BatchMode::parse(s)
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("bad value for --batch: {s:?} (on | off | auto)")),
        }
    }

    /// The `--comm full|restricted|delta` flag, shared by run/cycle/serve.
    fn comm(&self) -> anyhow::Result<Option<dydd_da::util::comm::CommMode>> {
        match self.get("--comm") {
            None => Ok(None),
            Some(s) => dydd_da::util::comm::CommMode::parse(s).map(Some).ok_or_else(|| {
                anyhow::anyhow!("bad value for --comm: {s:?} (full | restricted | delta)")
            }),
        }
    }
}

fn cmd_info() -> anyhow::Result<()> {
    println!("dydd-da {} — DyDD / DD-KF reproduction", env!("CARGO_PKG_VERSION"));
    let dir = runtime::default_artifacts_dir();
    println!("artifacts dir : {}", dir.display());
    println!(
        "pjrt feature  : {}",
        if runtime::pjrt_enabled() { "enabled" } else { "disabled (stub backend)" }
    );
    if runtime::artifacts_available(&dir) {
        let man = runtime::Manifest::load(&dir)?;
        println!("artifacts     : {} entries (manifest ok)", man.artifacts.len());
        runtime::with_engine(&dir, |eng| {
            // Touch the PJRT client to report the platform.
            let meta = eng
                .manifest()
                .pick_local_bucket(64, 32)
                .map(|(a, _)| a.clone())
                .expect("smallest bucket must exist");
            eng.executable(&meta)?;
            println!("pjrt          : CPU client ok, compiled {}", meta.name);
            Ok(())
        })?;
    } else if runtime::pjrt_enabled() {
        println!("artifacts     : NOT BUILT (run `make artifacts`) — native backend only");
    } else {
        println!(
            "artifacts     : unavailable without the `pjrt-xla` feature — native backend only"
        );
    }
    println!("cores         : {}", std::thread::available_parallelism()?.get());
    Ok(())
}

fn cmd_run(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let mut cfg = match f.get("--config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    let config_dim = cfg.dim;
    if let Some(d) = f.parsed::<usize>("--dim")? {
        cfg.dim = d;
        // Crossing the 1-D/2-D layout-family boundary orphans the config
        // file's layout choice (1-D/4-D and 2-D layouts live in separate
        // fields); be loud about falling back to the default rather than
        // silently swapping it. A 1 <-> 4 switch keeps cfg.layout, so no
        // warning there.
        if (d == 2) != (config_dim == 2) && f.get("--layout").is_none() {
            eprintln!(
                "warning: --dim {d} overrides the config's dim = {config_dim}; no --layout \
                 given, using the default ({})",
                if d == 2 { "uniform2d" } else { "uniform" }
            );
        }
    }
    // The default n = 2048 is a 1-D interval size; as a 2-D grid it means
    // 2048² unknowns, far past the dense local solvers. Pick a pipeline-
    // sized grid unless the user chose one explicitly — a config file's n
    // is honoured only when the config itself declares dim = 2 (a 1-D
    // config's n overridden by --dim 2 would be a multi-terabyte grid).
    if cfg.dim == 2 && f.get("--n").is_none() && config_dim != 2 {
        if f.get("--config").is_some() {
            eprintln!(
                "warning: --dim 2 overrides a dim-{config_dim} config; its n = {} is not a \
                 2-D grid axis, using the 2-D default n = 40 (pass --n to choose the grid)",
                cfg.n
            );
        }
        cfg.n = 40;
    }
    // Same reasoning for dim 4: the 1-D default n = 2048 would mean a
    // 2048 x steps trajectory with dense local window solves.
    if cfg.dim == 4 && f.get("--n").is_none() && config_dim != 4 {
        if f.get("--config").is_some() {
            eprintln!(
                "warning: --dim 4 overrides a dim-{config_dim} config; its n = {} is not a \
                 spatial trajectory size, using the 4-D default n = 24 (pass --n to choose)",
                cfg.n
            );
        }
        cfg.n = 24;
    }
    if let Some(n) = f.parsed::<usize>("--n")? {
        cfg.n = n;
    }
    if let Some(m) = f.parsed::<usize>("--m")? {
        cfg.m = m;
    }
    if let Some(p) = f.parsed::<usize>("--p")? {
        cfg.p = p;
    }
    if let Some(px) = f.parsed::<usize>("--px")? {
        cfg.px = px;
    }
    if let Some(py) = f.parsed::<usize>("--py")? {
        cfg.py = py;
    }
    if let Some(steps) = f.parsed::<usize>("--steps")? {
        cfg.steps = steps;
    }
    if let Some(s) = f.get("--layout") {
        match registry::parse_layout(cfg.dim, s)? {
            LayoutSpec::D1(l) => cfg.layout = l,
            LayoutSpec::D2(l) => cfg.layout2d = l,
        }
    }
    if let Some(b) = f.get("--backend") {
        cfg.backend =
            SolverBackend::parse(b).ok_or_else(|| anyhow::anyhow!("unknown backend {b:?}"))?;
    }
    if let Some(s) = f.parsed::<usize>("--overlap")? {
        cfg.schwarz.overlap = s;
    }
    if let Some(mu) = f.parsed::<f64>("--mu")? {
        cfg.schwarz.mu = mu;
    }
    if let Some(t) = f.parsed::<usize>("--threads")? {
        cfg.threads = t;
    }
    if let Some(b) = f.batch()? {
        cfg.batch = Some(b);
    }
    if let Some(w) = f.parsed::<usize>("--workers")? {
        cfg.workers = w;
    }
    if let Some(c) = f.comm()? {
        cfg.comm = Some(c);
    }
    if let Some(seed) = f.parsed::<u64>("--seed")? {
        cfg.seed = seed;
    }
    if f.has("--no-dydd") {
        cfg.dydd = false;
    }
    cfg.validate()?;

    let unknowns = match cfg.dim {
        2 => cfg.n * cfg.n,
        4 => cfg.n * cfg.steps,
        _ => cfg.n,
    };
    let with_baseline = baseline_enabled(f.has("--no-baseline"), unknowns);

    if cfg.dim == 2 {
        // Full 2-D pipeline: DyDD on the box grid, then the parallel DD-KF
        // solve over the rebalanced boxes, then the sequential-KF baseline.
        if f.has("--p") {
            eprintln!("warning: --p has no effect with --dim 2; use --px / --py");
        }
        println!(
            "run: dim=2 n={}x{} m={} grid={}x{} layout={} backend={:?} dydd={}",
            cfg.n,
            cfg.n,
            cfg.m,
            cfg.px,
            cfg.py,
            cfg.layout2d.name(),
            cfg.backend,
            cfg.dydd
        );
        let rep = run_experiment(&cfg, with_baseline)?;
        if let Some(d) = &rep.dydd {
            println!("l_in  (E = {:.3}):", balance_ratio(&d.dydd.l_in));
            print!("{}", census_grid(&d.dydd.l_in, cfg.px, cfg.py)?);
            println!("l_fin (E = {:.3}):", d.balance());
            print!("{}", census_grid(&d.census_after, cfg.px, cfg.py)?);
            println!(
                "dydd : T_DyDD={}  T_r={}",
                fmt_secs(d.dydd.t_dydd.as_secs_f64()),
                fmt_secs(d.dydd.t_repartition.as_secs_f64()),
            );
        }
        print_solve_report(&rep);
        return Ok(());
    }

    if cfg.dim == 4 {
        println!(
            "run: dim=4 n={} steps={} (nN={}) m={} windows={} layout={:?} drift-axis=time \
             backend={:?} dydd={}",
            cfg.n,
            cfg.steps,
            cfg.n * cfg.steps,
            cfg.m,
            cfg.p,
            cfg.layout,
            cfg.backend,
            cfg.dydd
        );
    } else {
        println!(
            "run: n={} m={} p={} layout={:?} backend={:?} dydd={}",
            cfg.n, cfg.m, cfg.p, cfg.layout, cfg.backend, cfg.dydd
        );
    }
    let rep = run_experiment(&cfg, with_baseline)?;
    if let Some(d) = &rep.dydd {
        println!(
            "dydd : l_in={:?} -> l_fin={:?}  E={:.3}  T_DyDD={}  T_r={}",
            d.dydd.l_in,
            d.census_after,
            d.balance(),
            fmt_secs(d.dydd.t_dydd.as_secs_f64()),
            fmt_secs(d.dydd.t_repartition.as_secs_f64()),
        );
    }
    print_solve_report(&rep);
    Ok(())
}

/// Multi-cycle assimilation: drifting observations, per-cycle DyDD policy
/// decisions, one persistent worker pool.
fn cmd_cycle(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let mut cfg = match f.get("--config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    let config_dim = cfg.dim;
    if let Some(d) = f.parsed::<usize>("--dim")? {
        cfg.dim = d;
    }
    // Same guard as `run`: a 1-D config's n is not a 2-D grid axis — and
    // the same loud note, so the substituted grid size is never a mystery.
    if cfg.dim == 2 && f.get("--n").is_none() && config_dim != 2 {
        if f.get("--config").is_some() {
            eprintln!(
                "warning: --dim 2 overrides a dim-{config_dim} config; its n = {} is not a \
                 2-D grid axis, using the 2-D cycle default n = 48 (pass --n to choose)",
                cfg.n
            );
        }
        cfg.n = 48;
    }
    if cfg.dim == 4 && f.get("--n").is_none() && config_dim != 4 {
        if f.get("--config").is_some() {
            eprintln!(
                "warning: --dim 4 overrides a dim-{config_dim} config; its n = {} is not a \
                 spatial trajectory size, using the 4-D cycle default n = 16 (pass --n)",
                cfg.n
            );
        }
        cfg.n = 16;
    }
    if let Some(n) = f.parsed::<usize>("--n")? {
        cfg.n = n;
    }
    if let Some(m) = f.parsed::<usize>("--m")? {
        cfg.m = m;
    }
    if let Some(p) = f.parsed::<usize>("--p")? {
        cfg.p = p;
    }
    if let Some(px) = f.parsed::<usize>("--px")? {
        cfg.px = px;
    }
    if let Some(py) = f.parsed::<usize>("--py")? {
        cfg.py = py;
    }
    if let Some(steps) = f.parsed::<usize>("--steps")? {
        cfg.steps = steps;
    }
    if let Some(k) = f.parsed::<usize>("--cycles")? {
        cfg.cycles = k;
    }
    if let Some(s) = f.get("--policy") {
        cfg.cycle_policy = RebalancePolicy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown policy {s:?}"))?;
    }
    if let Some(tau) = f.parsed::<f64>("--tau")? {
        anyhow::ensure!(
            matches!(cfg.cycle_policy, RebalancePolicy::Threshold(_)),
            "--tau only applies to --policy threshold"
        );
        cfg.cycle_policy = cfg.cycle_policy.with_tau(tau);
    }
    if let Some(s) = f.get("--drift") {
        match registry::parse_drift(cfg.dim, s)? {
            DriftSpec::D1(d) => cfg.drift = d,
            DriftSpec::D2(d) => cfg.drift2d = d,
        }
    }
    if let Some(b) = f.get("--backend") {
        cfg.backend =
            SolverBackend::parse(b).ok_or_else(|| anyhow::anyhow!("unknown backend {b:?}"))?;
    }
    if let Some(seed) = f.parsed::<u64>("--seed")? {
        cfg.seed = seed;
    }
    if let Some(t) = f.parsed::<usize>("--threads")? {
        cfg.threads = t;
    }
    if let Some(b) = f.batch()? {
        cfg.batch = Some(b);
    }
    if let Some(w) = f.parsed::<usize>("--workers")? {
        cfg.workers = w;
    }
    if let Some(c) = f.comm()? {
        cfg.comm = Some(c);
    }
    if f.has("--no-dydd") {
        cfg.dydd = false;
    }
    cfg.validate()?;
    let unknowns = match cfg.dim {
        2 => cfg.n * cfg.n,
        4 => cfg.n * cfg.steps,
        _ => cfg.n,
    };
    let with_baseline = baseline_enabled(f.has("--no-baseline"), unknowns);

    let drift_name = if cfg.dim == 2 { cfg.drift2d.name() } else { cfg.drift.name() };
    // `--no-dydd` forces the Never policy inside the driver; print what
    // will actually run, not the configured policy.
    let effective = if cfg.dydd { cfg.cycle_policy } else { RebalancePolicy::Never };
    println!(
        "cycle: dim={} n={} m={} {} K={} policy={} drift={} seed={}",
        cfg.dim,
        cfg.n,
        cfg.m,
        match cfg.dim {
            2 => format!("grid={}x{}", cfg.px, cfg.py),
            4 => format!("steps={} windows={}", cfg.steps, cfg.p),
            _ => format!("p={}", cfg.p),
        },
        cfg.cycles,
        effective.name(),
        drift_name,
        cfg.seed,
    );
    let rep = run_cycles(&cfg, with_baseline)?;
    print!("{}", render_cycle_table(&rep).render());
    println!(
        "summary: rebalances={}/{}  E_final={:.3}  E_mean={:.3}  E_worst={:.3}  \
         moved={}  T_DyDD/(T_DyDD+T^p)={:.3}",
        rep.rebalances(),
        rep.records.len(),
        rep.final_balance(),
        rep.mean_balance(),
        rep.worst_balance(),
        rep.total_migration_volume(),
        rep.rebalance_overhead_fraction(),
    );
    if !rep.all_converged() {
        eprintln!("warning: at least one cycle did not reach the Schwarz tolerance");
    }
    Ok(())
}

/// Streaming incremental assimilation: pull one observation delta per
/// tick, update the census in O(|delta|), re-extract only dirty blocks,
/// and emit one JSONL telemetry line per tick on stdout (headers and the
/// summary go to stderr so stdout stays machine-readable).
fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let mut cfg = match f.get("--config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    let config_dim = cfg.dim;
    if let Some(d) = f.parsed::<usize>("--dim")? {
        cfg.dim = d;
    }
    // Same guard as `cycle`: a 1-D config's n is not a 2-D grid axis.
    if cfg.dim == 2 && f.get("--n").is_none() && config_dim != 2 {
        if f.get("--config").is_some() {
            eprintln!(
                "warning: --dim 2 overrides a dim-{config_dim} config; its n = {} is not a \
                 2-D grid axis, using the 2-D serve default n = 48 (pass --n to choose)",
                cfg.n
            );
        }
        cfg.n = 48;
    }
    if cfg.dim == 4 && f.get("--n").is_none() && config_dim != 4 {
        if f.get("--config").is_some() {
            eprintln!(
                "warning: --dim 4 overrides a dim-{config_dim} config; its n = {} is not a \
                 spatial trajectory size, using the 4-D serve default n = 16 (pass --n)",
                cfg.n
            );
        }
        cfg.n = 16;
    }
    if let Some(n) = f.parsed::<usize>("--n")? {
        cfg.n = n;
    }
    if let Some(m) = f.parsed::<usize>("--m")? {
        cfg.m = m;
    }
    if let Some(p) = f.parsed::<usize>("--p")? {
        cfg.p = p;
    }
    if let Some(px) = f.parsed::<usize>("--px")? {
        cfg.px = px;
    }
    if let Some(py) = f.parsed::<usize>("--py")? {
        cfg.py = py;
    }
    if let Some(steps) = f.parsed::<usize>("--steps")? {
        cfg.steps = steps;
    }
    if let Some(k) = f.parsed::<usize>("--ticks")? {
        cfg.ticks = k;
    }
    if let Some(s) = f.get("--policy") {
        cfg.cycle_policy = RebalancePolicy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown policy {s:?}"))?;
    }
    if let Some(tau) = f.parsed::<f64>("--tau")? {
        anyhow::ensure!(
            matches!(cfg.cycle_policy, RebalancePolicy::Threshold(_)),
            "--tau only applies to --policy threshold"
        );
        cfg.cycle_policy = cfg.cycle_policy.with_tau(tau);
    }
    if let Some(s) = f.get("--drift") {
        match registry::parse_drift(cfg.dim, s)? {
            DriftSpec::D1(d) => cfg.drift = d,
            DriftSpec::D2(d) => cfg.drift2d = d,
        }
    }
    if let Some(b) = f.get("--backend") {
        cfg.backend =
            SolverBackend::parse(b).ok_or_else(|| anyhow::anyhow!("unknown backend {b:?}"))?;
    }
    if let Some(seed) = f.parsed::<u64>("--seed")? {
        cfg.seed = seed;
    }
    if let Some(s) = f.get("--source") {
        cfg.stream_source = StreamSourceConfig::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown source {s:?} (drift | replay | -)"))?;
    }
    if f.has("--no-dydd") {
        cfg.dydd = false;
    }
    if f.has("--no-feed-forward") {
        cfg.stream_feed_forward = false;
    }
    if f.has("--no-warm-start") {
        cfg.stream_warm_start = false;
    }
    if let Some(t) = f.parsed::<usize>("--threads")? {
        cfg.threads = t;
    }
    if let Some(b) = f.batch()? {
        cfg.batch = Some(b);
    }
    if let Some(w) = f.parsed::<usize>("--workers")? {
        cfg.workers = w;
    }
    if let Some(c) = f.comm()? {
        cfg.comm = Some(c);
    }
    if f.has("--force-cold") {
        cfg.stream_force_cold = true;
    }
    cfg.validate()?;
    // `serve` drives the stream engine directly (no pipeline entry
    // point), so the perf knobs are applied here.
    cfg.apply_threads();
    cfg.apply_batch();
    cfg.apply_workers();
    cfg.apply_comm();
    let unknowns = match cfg.dim {
        2 => cfg.n * cfg.n,
        4 => cfg.n * cfg.steps,
        _ => cfg.n,
    };
    let with_baseline = baseline_enabled(f.has("--no-baseline"), unknowns);

    let drift_name = if cfg.dim == 2 { cfg.drift2d.name() } else { cfg.drift.name() };
    let effective = if cfg.dydd { cfg.cycle_policy } else { RebalancePolicy::Never };
    eprintln!(
        "serve: dim={} n={} m={} {} ticks={} policy={} source={:?} drift={} seed={}",
        cfg.dim,
        cfg.n,
        cfg.m,
        match cfg.dim {
            2 => format!("grid={}x{}", cfg.px, cfg.py),
            4 => format!("steps={} windows={}", cfg.steps, cfg.p),
            _ => format!("p={}", cfg.p),
        },
        cfg.ticks,
        effective.name(),
        cfg.stream_source,
        drift_name,
        cfg.seed,
    );
    let rep = match cfg.dim {
        2 => serve_geometry(&cfg.box_geometry(), &cfg, with_baseline)?,
        4 => serve_geometry(&cfg.window_geometry(), &cfg, with_baseline)?,
        _ => serve_geometry(&cfg.interval_geometry(), &cfg, with_baseline)?,
    };
    eprintln!(
        "summary: ticks={}  m_final={}  factorizations={}  cache_hit_mean={:.3}  \
         warm_tick_wall_mean={}",
        rep.records.len(),
        rep.records.last().map(|r| r.m).unwrap_or(0),
        rep.total_factorizations(),
        rep.mean_cache_hit_rate(),
        fmt_secs(rep.mean_warm_tick_wall()),
    );
    if !rep.all_converged() {
        eprintln!("warning: at least one tick did not reach the Schwarz tolerance");
    }
    Ok(())
}

/// The dimension-generic half of `serve`: build the configured delta
/// source and drain it through a streaming engine, printing one JSONL
/// line per tick.
fn serve_geometry<G: RecordGeometry>(
    geom: &G,
    cfg: &ExperimentConfig,
    with_baseline: bool,
) -> anyhow::Result<StreamReport> {
    let opts = StreamOptions {
        policy: cfg.cycle_policy,
        dydd: cfg.dydd,
        schwarz: cfg.schwarz.clone(),
        backend: cfg.backend,
        artifacts_dir: cfg.artifacts_dir.clone(),
        feed_forward: cfg.stream_feed_forward,
        warm_start: cfg.stream_warm_start,
        force_cold: cfg.stream_force_cold,
        with_baseline,
    };
    let emit = |r: &dydd_da::stream::TickRecord| println!("{}", r.to_json());
    match cfg.stream_source {
        StreamSourceConfig::Stdin => {
            let stdin = std::io::stdin();
            let mut src = JsonlSource::new(stdin.lock());
            run_stream(geom, &mut src, &opts, emit)
        }
        StreamSourceConfig::Replay => {
            let mut src: ReplaySource<G> = ReplaySource::new(cfg.m, cfg.seed, cfg.ticks);
            run_stream(geom, &mut src, &opts, emit)
        }
        StreamSourceConfig::Drift => {
            match DriftSource::new(geom, cfg.m, cfg.seed, cfg.ticks) {
                Some(mut src) => run_stream(geom, &mut src, &opts, emit),
                None => {
                    eprintln!(
                        "note: this geometry/drift has no native stream; replaying \
                         per-tick cycle observations instead"
                    );
                    let mut src: ReplaySource<G> =
                        ReplaySource::new(cfg.m, cfg.seed, cfg.ticks);
                    run_stream(geom, &mut src, &opts, emit)
                }
            }
        }
    }
}

/// The DD-KF + baseline lines shared by the 1-D and 2-D run paths.
fn print_solve_report(rep: &ExperimentReport) {
    println!(
        "ddkf : iters={} converged={}{} T^p={}  T^p_crit={}  T_oh/T^p_crit={:.3}",
        rep.iters,
        rep.converged,
        if rep.stalled { " (stalled)" } else { "" },
        fmt_secs(rep.t_parallel.as_secs_f64()),
        fmt_secs(rep.t_critical.as_secs_f64()),
        rep.overhead_fraction,
    );
    if let (Some(t1), Some(err)) = (rep.t_sequential, rep.error_dd_da) {
        println!(
            "base : T^1={}  S^p={:.2}  E^p={:.2}  S^p_sim={:.2}  E^p_sim={:.2}  \
             error_DD-DA={err:.2e}",
            fmt_secs(t1.as_secs_f64()),
            rep.speedup().unwrap(),
            rep.efficiency().unwrap(),
            rep.speedup_sim().unwrap(),
            rep.efficiency_sim().unwrap(),
        );
    }
}

use dydd_da::harness::scenarios::render_census_grid as census_grid;

/// Run geometric DyDD on a 2-D scenario and report the paper's metrics.
fn run_dydd_2d(sc: &scenarios::Scenario2d) -> anyhow::Result<()> {
    let (px, py) = (sc.part.px(), sc.part.py());
    let l_in = sc.census();
    println!("l_in  (E = {:.3}):", balance_ratio(&l_in));
    print!("{}", census_grid(&l_in, px, py)?);
    // Only the decomposition core of the geometry is exercised here (the
    // scenario already carries its observations), so the default scenario
    // knobs are fine.
    let geom = BoxGeometry::new(sc.mesh.nx(), px, py);
    let out = rebalance(&geom, &sc.part, &sc.obs, &DyddParams::default())?;
    if let Some(lr) = &out.dydd.l_r {
        println!("l_r   (after DD repair step):");
        print!("{}", census_grid(lr, px, py)?);
    }
    println!("l_fin (realized census after edge shifting):");
    print!("{}", census_grid(&out.census_after, px, py)?);
    println!(
        "E = {:.3}   iters = {}   migrations = {}   T_DyDD = {}   T_r = {}",
        out.balance(),
        out.dydd.iters,
        out.dydd.migrations.len(),
        fmt_secs(out.dydd.t_dydd.as_secs_f64()),
        fmt_secs(out.dydd.t_repartition.as_secs_f64()),
    );
    Ok(())
}

fn cmd_dydd(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    if f.parsed::<usize>("--dim")? == Some(2) {
        for flag in ["--loads", "--graph"] {
            if f.has(flag) {
                eprintln!(
                    "warning: {flag} has no effect with --dim 2 (the box grid defines \
                     the graph and the generated layout defines the loads)"
                );
            }
        }
        let px = f.parsed::<usize>("--px")?.unwrap_or(4);
        let py = f.parsed::<usize>("--py")?.unwrap_or(4);
        let n = f.parsed::<usize>("--n")?.unwrap_or(512);
        let m = f.parsed::<usize>("--m")?.unwrap_or(2000);
        let seed = f.parsed::<u64>("--seed")?.unwrap_or(42);
        let layout = match f.get("--layout") {
            Some(s) => match registry::parse_layout(2, s)? {
                LayoutSpec::D2(l) => l,
                LayoutSpec::D1(_) => unreachable!("dim 2 resolves 2-D layouts"),
            },
            None => dydd_da::domain2d::ObsLayout2d::Uniform2d,
        };
        let sc = scenarios::grid2d(n, px, py, m, layout, seed)?;
        println!(
            "dydd: dim=2 n={n}x{n} m={m} grid={px}x{py} layout={} seed={seed}",
            layout.name()
        );
        return run_dydd_2d(&sc);
    }
    let loads: Vec<usize> = f
        .get("--loads")
        .ok_or_else(|| anyhow::anyhow!("--loads is required"))?
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad --loads: {e}"))?;
    let p = loads.len();
    let graph = match f.get("--graph").unwrap_or("chain") {
        "chain" => Graph::chain(p),
        "star" => Graph::star(p),
        "ring" => {
            let mut g = Graph::chain(p);
            if p > 2 {
                g.add_edge(0, p - 1);
            }
            g
        }
        other => anyhow::bail!("unknown graph {other:?}"),
    };
    let out = balance(&graph, &loads, &DyddParams::default())?;
    println!("l_in  = {:?}", out.l_in);
    if let Some(lr) = &out.l_r {
        println!("l_r   = {lr:?}   (after DD repair step)");
    }
    println!("l_fin = {:?}", out.l_fin);
    println!(
        "E = {:.3}   iters = {}   migrations = {}   T_DyDD = {}",
        out.balance(),
        out.iters,
        out.migrations.len(),
        fmt_secs(out.t_dydd.as_secs_f64())
    );
    Ok(())
}

fn cmd_table(args: &[String]) -> anyhow::Result<()> {
    let full = args.iter().any(|a| a == "--full");
    let which = args.first().ok_or_else(|| anyhow::anyhow!("table id required\n{USAGE}"))?;
    let ids: Vec<TableId> = if which == "all" {
        all_tables()
    } else {
        vec![TableId::parse(which).ok_or_else(|| anyhow::anyhow!("unknown table {which:?}"))?]
    };
    for id in ids {
        let t = render_table(id, full)?;
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_bench_tables(args: &[String]) -> anyhow::Result<()> {
    let full = args.iter().any(|a| a == "--full");
    for id in all_tables() {
        let t = render_table(id, full)?;
        println!("{}", t.render());
    }
    Ok(())
}
