//! `dydd-da` — CLI launcher for the DyDD / DD-KF framework.
//!
//! Subcommands:
//!   info                     platform, artifact and build information
//!   run [--config F] [...]   run one experiment (DyDD + DD-KF + baseline)
//!   dydd --loads a,b,c ...   run the load balancer on an abstract scenario
//!   table <1..12|fig5|all>   regenerate the paper's tables/figures
//!   bench-tables [--full]    regenerate everything (what EXPERIMENTS.md cites)

use dydd_da::config::ExperimentConfig;
use dydd_da::coordinator::SolverBackend;
use dydd_da::domain::ObsLayout;
use dydd_da::dydd::{balance, DyddParams};
use dydd_da::graph::Graph;
use dydd_da::harness::{all_tables, render_table, run_experiment, TableId};
use dydd_da::runtime;
use dydd_da::util::timer::fmt_secs;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("info") => cmd_info(),
        Some("run") => cmd_run(&args[1..]),
        Some("dydd") => cmd_dydd(&args[1..]),
        Some("table") => cmd_table(&args[1..]),
        Some("bench-tables") => cmd_bench_tables(&args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(anyhow::anyhow!("unknown subcommand {other:?}\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
dydd-da — Parallel Dynamic Domain Decomposition for Data Assimilation

USAGE:
  dydd-da info
  dydd-da run [--config FILE] [--n N] [--m M] [--p P] [--layout L]
              [--backend native|kf|pjrt] [--overlap S] [--mu MU]
              [--no-dydd] [--seed SEED] [--no-baseline]
  dydd-da dydd --loads L1,L2,... [--graph chain|star|ring]
  dydd-da table <1..12|fig5|all> [--full]
  dydd-da bench-tables [--full]

Layouts: uniform | ramp | cluster | two_clusters | left_packed
";

/// Tiny flag parser: `--key value` and boolean `--flag`.
struct Flags<'a> {
    args: &'a [String],
}

impl<'a> Flags<'a> {
    fn get(&self, key: &str) -> Option<&'a str> {
        self.args
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.args.get(i + 1))
            .map(String::as_str)
    }

    fn has(&self, key: &str) -> bool {
        self.args.iter().any(|a| a == key)
    }

    fn parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("bad value for {key}: {v:?}")),
        }
    }
}

fn cmd_info() -> anyhow::Result<()> {
    println!("dydd-da {} — DyDD / DD-KF reproduction", env!("CARGO_PKG_VERSION"));
    let dir = runtime::default_artifacts_dir();
    println!("artifacts dir : {}", dir.display());
    if runtime::artifacts_available(&dir) {
        let man = runtime::Manifest::load(&dir)?;
        println!("artifacts     : {} entries (manifest ok)", man.artifacts.len());
        runtime::with_engine(&dir, |eng| {
            // Touch the PJRT client to report the platform.
            let meta = eng
                .manifest()
                .pick_local_bucket(64, 32)
                .map(|(a, _)| a.clone())
                .expect("smallest bucket must exist");
            eng.executable(&meta)?;
            println!("pjrt          : CPU client ok, compiled {}", meta.name);
            Ok(())
        })?;
    } else {
        println!("artifacts     : NOT BUILT (run `make artifacts`) — native backend only");
    }
    println!("cores         : {}", std::thread::available_parallelism()?.get());
    Ok(())
}

fn parse_layout(s: &str) -> anyhow::Result<ObsLayout> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "uniform" => ObsLayout::Uniform,
        "ramp" => ObsLayout::Ramp,
        "cluster" => ObsLayout::Cluster,
        "two_clusters" => ObsLayout::TwoClusters,
        "left_packed" => ObsLayout::LeftPacked,
        other => anyhow::bail!("unknown layout {other:?}"),
    })
}

fn cmd_run(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let mut cfg = match f.get("--config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(n) = f.parsed::<usize>("--n")? {
        cfg.n = n;
    }
    if let Some(m) = f.parsed::<usize>("--m")? {
        cfg.m = m;
    }
    if let Some(p) = f.parsed::<usize>("--p")? {
        cfg.p = p;
    }
    if let Some(s) = f.get("--layout") {
        cfg.layout = parse_layout(s)?;
    }
    if let Some(b) = f.get("--backend") {
        cfg.backend =
            SolverBackend::parse(b).ok_or_else(|| anyhow::anyhow!("unknown backend {b:?}"))?;
    }
    if let Some(s) = f.parsed::<usize>("--overlap")? {
        cfg.schwarz.overlap = s;
    }
    if let Some(mu) = f.parsed::<f64>("--mu")? {
        cfg.schwarz.mu = mu;
    }
    if let Some(seed) = f.parsed::<u64>("--seed")? {
        cfg.seed = seed;
    }
    if f.has("--no-dydd") {
        cfg.dydd = false;
    }
    cfg.validate()?;

    let with_baseline = !f.has("--no-baseline");
    println!(
        "run: n={} m={} p={} layout={:?} backend={:?} dydd={}",
        cfg.n, cfg.m, cfg.p, cfg.layout, cfg.backend, cfg.dydd
    );
    let rep = run_experiment(&cfg, with_baseline)?;
    if let Some(d) = &rep.dydd {
        println!(
            "dydd : l_in={:?} -> l_fin={:?}  E={:.3}  T_DyDD={}  T_r={}",
            d.dydd.l_in,
            d.census_after,
            d.balance(),
            fmt_secs(d.dydd.t_dydd.as_secs_f64()),
            fmt_secs(d.dydd.t_repartition.as_secs_f64()),
        );
    }
    println!(
        "ddkf : iters={} converged={} T^p={}",
        rep.iters,
        rep.converged,
        fmt_secs(rep.t_parallel.as_secs_f64())
    );
    if let (Some(t1), Some(err)) = (rep.t_sequential, rep.error_dd_da) {
        println!(
            "base : T^1={}  S^p={:.2}  E^p={:.2}  error_DD-DA={err:.2e}",
            fmt_secs(t1.as_secs_f64()),
            rep.speedup().unwrap(),
            rep.efficiency().unwrap(),
        );
    }
    Ok(())
}

fn cmd_dydd(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let loads: Vec<usize> = f
        .get("--loads")
        .ok_or_else(|| anyhow::anyhow!("--loads is required"))?
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad --loads: {e}"))?;
    let p = loads.len();
    let graph = match f.get("--graph").unwrap_or("chain") {
        "chain" => Graph::chain(p),
        "star" => Graph::star(p),
        "ring" => {
            let mut g = Graph::chain(p);
            if p > 2 {
                g.add_edge(0, p - 1);
            }
            g
        }
        other => anyhow::bail!("unknown graph {other:?}"),
    };
    let out = balance(&graph, &loads, &DyddParams::default())?;
    println!("l_in  = {:?}", out.l_in);
    if let Some(lr) = &out.l_r {
        println!("l_r   = {lr:?}   (after DD repair step)");
    }
    println!("l_fin = {:?}", out.l_fin);
    println!(
        "E = {:.3}   iters = {}   migrations = {}   T_DyDD = {}",
        out.balance(),
        out.iters,
        out.migrations.len(),
        fmt_secs(out.t_dydd.as_secs_f64())
    );
    Ok(())
}

fn cmd_table(args: &[String]) -> anyhow::Result<()> {
    let full = args.iter().any(|a| a == "--full");
    let which = args.first().ok_or_else(|| anyhow::anyhow!("table id required\n{USAGE}"))?;
    let ids: Vec<TableId> = if which == "all" {
        all_tables()
    } else {
        vec![TableId::parse(which).ok_or_else(|| anyhow::anyhow!("unknown table {which:?}"))?]
    };
    for id in ids {
        let t = render_table(id, full)?;
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_bench_tables(args: &[String]) -> anyhow::Result<()> {
    let full = args.iter().any(|a| a == "--full");
    for id in all_tables() {
        let t = render_table(id, full)?;
        println!("{}", t.render());
    }
    Ok(())
}
