//! Solver for the singular Laplacian system `L λ = b` of the scheduling
//! step.
//!
//! L is symmetric positive semi-definite with kernel = span{1} for a
//! connected graph; b (the load imbalance) always satisfies 1^T b = 0, so
//! the system is consistent and the solution is unique up to a constant —
//! which is irrelevant because only differences λ_i − λ_j are used.
//!
//! For the small p of the scheduling step we *ground* one vertex (fix
//! λ_0 = 0, drop its row/column) and solve the resulting SPD system by
//! Cholesky; a conjugate-gradient path is provided for large p and as a
//! cross-check (property tests assert both agree).

use super::Graph;
use crate::linalg::{Cholesky, Mat};

#[derive(Debug, thiserror::Error)]
pub enum LaplacianSolveError {
    #[error("graph is disconnected; Laplacian system is not solvable per-component")]
    Disconnected,
    #[error("imbalance does not sum to zero (sum = {0:.3e}); system inconsistent")]
    Inconsistent(f64),
    #[error("grounded Laplacian not SPD: {0}")]
    NotSpd(#[from] crate::linalg::chol::NotSpd),
}

/// Solve `L λ = b`, returning the mean-zero representative.
pub fn laplacian_solve(g: &Graph, b: &[f64]) -> Result<Vec<f64>, LaplacianSolveError> {
    let p = g.p();
    assert_eq!(b.len(), p);
    if p == 0 {
        return Ok(vec![]);
    }
    if p == 1 {
        return Ok(vec![0.0]);
    }
    if !g.is_connected() {
        return Err(LaplacianSolveError::Disconnected);
    }
    let s: f64 = b.iter().sum();
    let scale = b.iter().fold(1.0_f64, |m, x| m.max(x.abs()));
    if s.abs() > 1e-9 * scale {
        return Err(LaplacianSolveError::Inconsistent(s));
    }

    let l = g.laplacian();
    // Ground vertex 0: solve the (p-1)x(p-1) principal minor.
    let mut lg = Mat::zeros(p - 1, p - 1);
    for i in 1..p {
        for j in 1..p {
            lg[(i - 1, j - 1)] = l[(i, j)];
        }
    }
    let rhs: Vec<f64> = b[1..].to_vec();
    let sol = Cholesky::new(&lg)?.solve(&rhs);

    let mut lambda = Vec::with_capacity(p);
    lambda.push(0.0);
    lambda.extend(sol);
    // Shift to mean zero (canonical representative).
    let mean = lambda.iter().sum::<f64>() / p as f64;
    for v in &mut lambda {
        *v -= mean;
    }
    Ok(lambda)
}

/// Conjugate gradient on the full singular system, projected onto the
/// mean-zero subspace. Used as a cross-check and for very large p.
pub fn laplacian_solve_cg(
    g: &Graph,
    b: &[f64],
    tol: f64,
    max_iter: usize,
) -> Result<Vec<f64>, LaplacianSolveError> {
    let p = g.p();
    assert_eq!(b.len(), p);
    if p <= 1 {
        return Ok(vec![0.0; p]);
    }
    if !g.is_connected() {
        return Err(LaplacianSolveError::Disconnected);
    }
    let project = |v: &mut Vec<f64>| {
        let m = v.iter().sum::<f64>() / p as f64;
        for x in v.iter_mut() {
            *x -= m;
        }
    };
    let matvec = |x: &[f64]| -> Vec<f64> {
        let mut y: Vec<f64> = (0..p).map(|i| g.degree(i) as f64 * x[i]).collect();
        for (a, c) in g.edges() {
            y[a] -= x[c];
            y[c] -= x[a];
        }
        y
    };

    let mut bb = b.to_vec();
    project(&mut bb);
    let mut x = vec![0.0; p];
    let mut r = bb.clone();
    let mut d = r.clone();
    let mut rs: f64 = r.iter().map(|v| v * v).sum();
    let b_norm = rs.sqrt().max(1e-300);
    for _ in 0..max_iter {
        if rs.sqrt() <= tol * b_norm {
            break;
        }
        let ad = matvec(&d);
        let dad: f64 = d.iter().zip(&ad).map(|(a, b)| a * b).sum();
        let alpha = rs / dad;
        for i in 0..p {
            x[i] += alpha * d[i];
            r[i] -= alpha * ad[i];
        }
        let rs_new: f64 = r.iter().map(|v| v * v).sum();
        let beta = rs_new / rs;
        rs = rs_new;
        for i in 0..p {
            d[i] = r[i] + beta * d[i];
        }
    }
    project(&mut x);
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::dist2;
    use crate::util::Rng;

    fn residual(g: &Graph, lambda: &[f64], b: &[f64]) -> f64 {
        let l = g.laplacian();
        dist2(&l.matvec(lambda), b)
    }

    fn balanced_b(g: &Graph, rng: &mut Rng) -> Vec<f64> {
        let p = g.p();
        let mut b: Vec<f64> = (0..p).map(|_| (rng.below(21) as f64) - 10.0).collect();
        let mean = b.iter().sum::<f64>() / p as f64;
        for v in &mut b {
            *v -= mean;
        }
        b
    }

    #[test]
    fn paper_example_schedule() {
        // Loads from Figure 1(b): l = (5,4,6,2,5,3,5,2), average 4.
        let g = Graph::paper_example();
        let loads = [5.0, 4.0, 6.0, 2.0, 5.0, 3.0, 5.0, 2.0];
        let avg = 4.0;
        let b: Vec<f64> = loads.iter().map(|l| l - avg).collect();
        let lambda = laplacian_solve(&g, &b).unwrap();
        assert!(residual(&g, &lambda, &b) < 1e-10);
        // Diffusion property: total migrated load out of each vertex equals
        // its surplus: sum_j (λ_i − λ_j) over edges = b_i.
        for i in 0..8 {
            let flow: f64 = g.neighbours(i).iter().map(|&j| lambda[i] - lambda[j]).sum();
            assert!((flow - b[i]).abs() < 1e-10, "vertex {i}");
        }
    }

    #[test]
    fn grounded_and_cg_agree() {
        let mut rng = Rng::new(10);
        for p in [2usize, 3, 8, 17] {
            for g in [Graph::chain(p), Graph::star(p)] {
                let b = balanced_b(&g, &mut rng);
                let a = laplacian_solve(&g, &b).unwrap();
                let c = laplacian_solve_cg(&g, &b, 1e-12, 10 * p).unwrap();
                assert!(dist2(&a, &c) < 1e-8, "p={p}");
            }
        }
    }

    #[test]
    fn rejects_inconsistent() {
        let g = Graph::chain(3);
        assert!(matches!(
            laplacian_solve(&g, &[1.0, 1.0, 1.0]),
            Err(LaplacianSolveError::Inconsistent(_))
        ));
    }

    #[test]
    fn rejects_disconnected() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        assert!(matches!(
            laplacian_solve(&g, &[1.0, -1.0, 2.0, -2.0]),
            Err(LaplacianSolveError::Disconnected)
        ));
    }

    #[test]
    fn trivial_sizes() {
        assert_eq!(laplacian_solve(&Graph::new(1), &[0.0]).unwrap(), vec![0.0]);
        let g = Graph::chain(2);
        let lam = laplacian_solve(&g, &[3.0, -3.0]).unwrap();
        assert!((lam[0] - lam[1] - 3.0).abs() < 1e-12);
    }
}
