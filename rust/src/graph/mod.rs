//! Decomposition-graph substrate for the DyDD scheduling step.
//!
//! Vertices are subdomains; edges connect adjacent subdomains. The
//! scheduling step (paper §5, Table 13) solves the graph-Laplacian system
//! `L λ = b` (b = per-vertex load imbalance) and migrates
//! `δ_{ij} = round(λ_i − λ_j)` observations across each edge — the
//! diffusion-type schedule of Hu–Blake–Emerson (ref. 18) minimizing the
//! Euclidean norm of data movement.

mod solver;

pub use solver::{laplacian_solve, laplacian_solve_cg, LaplacianSolveError};

use crate::linalg::Mat;
use std::collections::BTreeSet;

/// Undirected decomposition graph on `p` vertices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    p: usize,
    /// Sorted unique edges (i < j).
    edges: BTreeSet<(usize, usize)>,
}

impl Graph {
    pub fn new(p: usize) -> Self {
        Graph { p, edges: BTreeSet::new() }
    }

    /// Chain topology: 0-1-2-…-(p-1). Example 4's configuration
    /// (deg(1) = deg(p) = 1, interior degree 2).
    pub fn chain(p: usize) -> Self {
        let mut g = Graph::new(p);
        for i in 1..p {
            g.add_edge(i - 1, i);
        }
        g
    }

    /// Star topology: vertex 0 adjacent to all others. Example 3's
    /// configuration (deg(1) = p−1, deg(i) = 1 otherwise).
    pub fn star(p: usize) -> Self {
        let mut g = Graph::new(p);
        for i in 1..p {
            g.add_edge(0, i);
        }
        g
    }

    /// The 8-subdomain graph of the paper's Figures 1-4 / eq. (30).
    pub fn paper_example() -> Self {
        let mut g = Graph::new(8);
        // Edges read off the printed Laplacian (1-based in the paper):
        // 1-2, 1-3, 2-3, 2-4, 3-4, 3-5, 5-6, 6-7, 6-8, 7-8.
        for (a, b) in [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (2, 4), (4, 5), (5, 6), (5, 7), (6, 7)]
        {
            g.add_edge(a, b);
        }
        g
    }

    pub fn add_edge(&mut self, a: usize, b: usize) {
        assert!(a != b, "self loop");
        assert!(a < self.p && b < self.p, "vertex out of range");
        self.edges.insert((a.min(b), a.max(b)));
    }

    pub fn remove_edge(&mut self, a: usize, b: usize) {
        self.edges.remove(&(a.min(b), a.max(b)));
    }

    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.edges.contains(&(a.min(b), a.max(b)))
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn edges(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.edges.iter().copied()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn degree(&self, v: usize) -> usize {
        self.edges.iter().filter(|&&(a, b)| a == v || b == v).count()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.p).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    pub fn neighbours(&self, v: usize) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .edges
            .iter()
            .filter_map(|&(a, b)| {
                if a == v {
                    Some(b)
                } else if b == v {
                    Some(a)
                } else {
                    None
                }
            })
            .collect();
        out.sort_unstable();
        out
    }

    /// Graph Laplacian per eq. (29): L_ii = deg(i), L_ij = −1 on edges.
    pub fn laplacian(&self) -> Mat {
        let mut l = Mat::zeros(self.p, self.p);
        for v in 0..self.p {
            l[(v, v)] = self.degree(v) as f64;
        }
        for &(a, b) in &self.edges {
            l[(a, b)] = -1.0;
            l[(b, a)] = -1.0;
        }
        l
    }

    /// Connectivity check (DFS) — DyDD requires a connected decomposition.
    pub fn is_connected(&self) -> bool {
        if self.p == 0 {
            return true;
        }
        let mut seen = vec![false; self.p];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for w in self.neighbours(v) {
                if !seen[w] {
                    seen[w] = true;
                    stack.push(w);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_and_star_degrees() {
        let c = Graph::chain(5);
        assert_eq!(c.degree(0), 1);
        assert_eq!(c.degree(2), 2);
        assert_eq!(c.num_edges(), 4);
        let s = Graph::star(5);
        assert_eq!(s.degree(0), 4);
        assert_eq!(s.degree(3), 1);
        assert!(c.is_connected() && s.is_connected());
    }

    #[test]
    fn paper_laplacian_matches_eq30() {
        // The printed 8x8 Laplacian of eq. (30).
        #[rustfmt::skip]
        let want: [[f64; 8]; 8] = [
            [ 2.0, -1.0, -1.0,  0.0,  0.0,  0.0,  0.0,  0.0],
            [-1.0,  3.0, -1.0, -1.0,  0.0,  0.0,  0.0,  0.0],
            [-1.0, -1.0,  4.0, -1.0, -1.0,  0.0,  0.0,  0.0],
            [ 0.0, -1.0, -1.0,  2.0,  0.0,  0.0,  0.0,  0.0],
            [ 0.0,  0.0, -1.0,  0.0,  2.0, -1.0,  0.0,  0.0],
            [ 0.0,  0.0,  0.0,  0.0, -1.0,  3.0, -1.0, -1.0],
            [ 0.0,  0.0,  0.0,  0.0,  0.0, -1.0,  2.0, -1.0],
            [ 0.0,  0.0,  0.0,  0.0,  0.0, -1.0, -1.0,  2.0],
        ];
        let l = Graph::paper_example().laplacian();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(l[(i, j)], want[i][j], "L[{i}][{j}]");
            }
        }
    }

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let g = Graph::paper_example();
        let l = g.laplacian();
        for i in 0..g.p() {
            let s: f64 = (0..g.p()).map(|j| l[(i, j)]).sum();
            assert_eq!(s, 0.0);
        }
    }

    #[test]
    fn disconnected_detected() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        assert!(!g.is_connected());
    }

    #[test]
    fn neighbours_sorted() {
        let g = Graph::paper_example();
        assert_eq!(g.neighbours(2), vec![0, 1, 3, 4]);
    }
}
