//! Loom model checking of the leader/worker protocol replica.
//!
//! Each `loom::model` body below is one coordinator scenario run on real
//! (loom-virtualized) threads over the instrumented channel in
//! `dydd_loom::chan`. Loom exhaustively explores thread schedules and
//! memory orderings; a deadlock or lost wakeup in any schedule fails the
//! test. Run with:
//!
//!   RUSTFLAGS="--cfg loom" cargo test --manifest-path verify/loom/Cargo.toml \
//!       --release --test loom_coordinator
#![cfg(loom)]

use dydd_da::coordinator::protocol::{Rep, Req, WorkerModel};
use dydd_loom::chan::{channel, Receiver, Sender};
use loom::sync::atomic::{AtomicBool, Ordering};
use loom::sync::Arc;
use loom::thread;

/// `worker_main` over the replica: serve messages until `Shutdown`, a
/// protocol error, or leader disconnect; flag the thread as finished on
/// the way out (the loom stand-in for `JoinHandle::is_finished`).
fn worker(id: usize, rx: Receiver<Req>, tx: Sender<Rep>, finished: Arc<AtomicBool>) {
    let mut wm = WorkerModel::new(id);
    while let Ok(req) = rx.recv() {
        match wm.step(req) {
            Some(rep) => {
                if tx.send(rep).is_err() {
                    break;
                }
            }
            None => break,
        }
        if wm.stopped {
            break;
        }
    }
    finished.store(true, Ordering::Release);
}

/// The fixed leader's receive: drain the queue first, then consult the
/// liveness flags — the loom mirror of `WorkerPool::recv_diagnosed`.
/// Returns `Err(worker)` when a finished worker is diagnosed.
fn recv_diagnosed(
    from_workers: &Receiver<Rep>,
    finished: &[Arc<AtomicBool>],
) -> Result<Rep, usize> {
    loop {
        if let Some(rep) = from_workers.try_recv() {
            return Ok(rep);
        }
        if let Some(dead) = finished.iter().position(|f| f.load(Ordering::Acquire)) {
            // One more drain before bailing: anything the worker managed
            // to send before dying must not be lost.
            if let Some(rep) = from_workers.try_recv() {
                return Ok(rep);
            }
            return Err(dead);
        }
        thread::yield_now();
    }
}

struct Pool {
    to_workers: Vec<Sender<Req>>,
    from_workers: Receiver<Rep>,
    finished: Vec<Arc<AtomicBool>>,
    joins: Vec<thread::JoinHandle<()>>,
}

fn spawn_pool(p: usize) -> Pool {
    let (to_leader, from_workers) = channel::<Rep>();
    let mut to_workers = Vec::new();
    let mut finished = Vec::new();
    let mut joins = Vec::new();
    for id in 0..p {
        let (tx, rx) = channel::<Req>();
        to_workers.push(tx);
        let ltx = to_leader.clone();
        let fin = Arc::new(AtomicBool::new(false));
        finished.push(fin.clone());
        joins.push(thread::spawn(move || worker(id, rx, ltx, fin)));
    }
    drop(to_leader);
    Pool { to_workers, from_workers, finished, joins }
}

/// Solve dispatch + epoch reuse: Setup/solve, then Retain+RefreshB/solve.
/// Every schedule must complete with epoch-consistent solutions and shut
/// down cleanly.
#[test]
fn solve_dispatch_and_epoch_reuse_complete() {
    loom::model(|| {
        let pool = spawn_pool(2);
        // Epoch 0: extract both blocks, await both acks.
        for tx in &pool.to_workers {
            tx.send(Req::Setup { epoch: 0 }).unwrap();
        }
        for _ in 0..2 {
            let rep = pool.from_workers.recv().unwrap();
            assert!(matches!(rep, Rep::Ready { .. }), "{rep:?}");
        }
        // Epoch 1: pure cache reuse, then one two-phase sweep.
        pool.to_workers[0].send(Req::Retain { epoch: 0 }).unwrap();
        pool.to_workers[1].send(Req::RefreshB { epoch: 0 }).unwrap();
        for _ in 0..2 {
            let rep = pool.from_workers.recv().unwrap();
            assert!(matches!(rep, Rep::Ready { .. }), "{rep:?}");
        }
        for (i, tx) in pool.to_workers.iter().enumerate() {
            tx.send(Req::Solve).unwrap();
            match pool.from_workers.recv().unwrap() {
                Rep::Solution { worker, epoch } => assert_eq!((worker, epoch), (i, 0)),
                other => panic!("unexpected {other:?}"),
            }
        }
        for tx in &pool.to_workers {
            tx.send(Req::Shutdown).unwrap();
        }
        for j in pool.joins {
            j.join().unwrap();
        }
    });
}

/// Worker death mid-assemble: the victim consumes its `Setup` and unwinds
/// without replying. The healthy worker's sender keeps the shared channel
/// connected, so a blocking `recv` would deadlock — the polling leader
/// must diagnose the victim in every schedule, without losing anything
/// the healthy worker sent.
#[test]
fn worker_death_is_diagnosed_not_deadlocked() {
    loom::model(|| {
        let (to_leader, from_workers) = channel::<Rep>();
        let finished =
            vec![Arc::new(AtomicBool::new(false)), Arc::new(AtomicBool::new(false))];
        let mut to_workers = Vec::new();
        let mut joins = Vec::new();
        // Worker 0: healthy.
        let (tx0, rx0) = channel::<Req>();
        to_workers.push(tx0);
        let ltx = to_leader.clone();
        let fin = finished[0].clone();
        joins.push(thread::spawn(move || worker(0, rx0, ltx, fin)));
        // Worker 1: dies handling its first message (panicking solver).
        let (tx1, rx1) = channel::<Req>();
        to_workers.push(tx1);
        let ltx = to_leader.clone();
        let fin = finished[1].clone();
        joins.push(thread::spawn(move || {
            let _ = rx1.recv();
            drop(ltx); // unwind: sender dropped, no reply
            fin.store(true, Ordering::Release);
        }));
        drop(to_leader);

        for tx in &to_workers {
            tx.send(Req::Setup { epoch: 0 }).unwrap();
        }
        let mut readys = 0;
        let diagnosed = loop {
            match recv_diagnosed(&from_workers, &finished) {
                Ok(Rep::Ready { .. }) => readys += 1,
                Ok(other) => panic!("unexpected {other:?}"),
                Err(dead) => break dead,
            }
            assert!(readys <= 1, "the victim never acknowledges");
        };
        assert_eq!(diagnosed, 1, "diagnosis must name the victim");
        // Drop-time shutdown with a dead worker: the failed send to the
        // victim is ignored, the healthy worker still joins.
        let _ = to_workers[0].send(Req::Shutdown);
        let _ = to_workers[1].send(Req::Shutdown);
        for j in joins {
            let _ = j.join();
        }
    });
}

/// Drop-time shutdown: one worker is told to stop, the other observes the
/// leader hanging up (every sender dropped). Both paths must wake a
/// blocked `recv` — no lost wakeup, no leaked thread.
#[test]
fn shutdown_and_disconnect_terminate_workers() {
    loom::model(|| {
        let mut pool = spawn_pool(2);
        pool.to_workers[0].send(Req::Shutdown).unwrap();
        pool.to_workers.clear(); // worker 1 sees the disconnect
        for j in pool.joins.drain(..) {
            j.join().unwrap();
        }
        assert!(pool.finished.iter().all(|f| f.load(Ordering::Acquire)));
        // With every worker gone the shared reply channel reports
        // disconnect instead of blocking the leader forever.
        assert!(pool.from_workers.recv().is_err());
    });
}
