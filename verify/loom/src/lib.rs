//! Loom-instrumented plumbing for model checking the coordinator
//! protocol replica (`dydd_da::coordinator::protocol`).
//!
//! The real coordinator communicates over `std::sync::mpsc`, which loom
//! cannot instrument. [`chan`] is a small faithful replica — FIFO
//! ordering, multi-producer/single-consumer, blocking `recv`, disconnect
//! when the last sender (or the receiver) drops — built from loom's
//! `Mutex`/`Condvar` so the model checker can explore every schedule and
//! every memory ordering, including the lost-wakeup and deadlock classes
//! the exhaustive DFS in `coordinator::model` abstracts away.
//!
//! The scenarios live in `tests/loom_coordinator.rs` and are gated on
//! `--cfg loom` (see rust/README.md, "Correctness tooling").

pub mod chan {
    use loom::sync::{Arc, Condvar, Mutex};
    use std::collections::VecDeque;

    struct Inner<T> {
        queue: VecDeque<T>,
        senders: usize,
        receiver_alive: bool,
    }

    struct Shared<T> {
        inner: Mutex<Inner<T>>,
        ready: Condvar,
    }

    pub struct Sender<T>(Arc<Shared<T>>);
    pub struct Receiver<T>(Arc<Shared<T>>);

    /// The receiver is gone; the value could not be delivered.
    #[derive(Debug, PartialEq, Eq)]
    pub struct SendError;

    /// Every sender is gone and the queue is drained.
    #[derive(Debug, PartialEq, Eq)]
    pub struct RecvError;

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                senders: 1,
                receiver_alive: true,
            }),
            ready: Condvar::new(),
        });
        (Sender(shared.clone()), Receiver(shared))
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            self.0.inner.lock().unwrap().senders += 1;
            Sender(self.0.clone())
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut inner = self.0.inner.lock().unwrap();
            inner.senders -= 1;
            if inner.senders == 0 {
                // Last sender gone: wake a blocked recv so it reports the
                // disconnect instead of sleeping forever (the lost-wakeup
                // hazard this harness exists to check).
                self.0.ready.notify_all();
            }
        }
    }

    impl<T> Sender<T> {
        pub fn send(&self, value: T) -> Result<(), SendError> {
            let mut inner = self.0.inner.lock().unwrap();
            if !inner.receiver_alive {
                return Err(SendError);
            }
            inner.queue.push_back(value);
            self.0.ready.notify_all();
            Ok(())
        }
    }

    impl<T> Receiver<T> {
        /// Block until a value arrives or every sender is gone.
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut inner = self.0.inner.lock().unwrap();
            loop {
                if let Some(v) = inner.queue.pop_front() {
                    return Ok(v);
                }
                if inner.senders == 0 {
                    return Err(RecvError);
                }
                inner = self.0.ready.wait(inner).unwrap();
            }
        }

        /// Non-blocking pop — the polling primitive `recv_diagnosed`-style
        /// leaders use alongside thread-liveness flags.
        pub fn try_recv(&self) -> Option<T> {
            self.0.inner.lock().unwrap().queue.pop_front()
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            self.0.inner.lock().unwrap().receiver_alive = false;
        }
    }
}
