"""Census/trigger simulator for the multi-cycle DyDD acceptance scenario.

No Rust toolchain is available in the authoring container, so the
acceptance-test constants (drift path, blob width, tau, grid sizes) in
`rust/tests/integration.rs::cycle_policies_acceptance_*` and
`examples/dydd_cycles.rs` were tuned with this exact-arithmetic port and
cross-checked across seeds. Keep it in sync with the Rust side when
changing the TranslatingBlob constants or `harness::cycles::cycle_rng`.

Run:  python3 python/tools/cycle_census_sim.py

Mirrors the planned Rust implementation exactly where it matters for the
census/trigger arithmetic:
  - SplitMix64 Rng (integer-exact port)
  - stratified TranslatingBlob drift generator (1D and 2D)
  - mesh nearest-point census
  - Partition::from_targets (1D) and the 2D x-sweep/y-sweep realization
  - threshold policy decisions

l_fin targets are exactly m/p when p | m (balance() + polish guarantee
max-min<=1 and conservation => all equal), so balance() itself is not
ported.
"""
import math

M64 = (1 << 64) - 1


class Rng:
    def __init__(self, seed):
        self.s = seed & M64

    def next_u64(self):
        self.s = (self.s + 0x9E3779B97F4A7C15) & M64
        z = self.s
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return z ^ (z >> 31)

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


# Acklam inverse normal CDF
A = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
     1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
B = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
     6.680131188771972e+01, -1.328068155288572e+01]
C = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
     -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
D = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
     3.754408661907416e+00]


def norm_quantile(p):
    p = min(max(p, 1e-300), 1.0 - 1e-16)
    if p < 0.02425:
        q = math.sqrt(-2.0 * math.log(p))
        return (((((C[0]*q+C[1])*q+C[2])*q+C[3])*q+C[4])*q+C[5]) / \
               ((((D[0]*q+D[1])*q+D[2])*q+D[3])*q+1.0)
    elif p <= 1.0 - 0.02425:
        q = p - 0.5
        r = q*q
        return (((((A[0]*r+A[1])*r+A[2])*r+A[3])*r+A[4])*r+A[5])*q / \
               (((((B[0]*r+B[1])*r+B[2])*r+B[3])*r+B[4])*r+1.0)
    else:
        q = math.sqrt(-2.0 * math.log(1.0 - p))
        return -(((((C[0]*q+C[1])*q+C[2])*q+C[3])*q+C[4])*q+C[5]) / \
               ((((D[0]*q+D[1])*q+D[2])*q+D[3])*q+1.0)


def clamp01(x):
    return min(max(x, 0.0), 1.0 - 1e-12)


# ---------------- 1D ----------------

def drift_blob_1d(m, t, rng, mu0, path, sigma):
    mu = mu0 + path * t
    m_u = m // 2
    m_b = m - m_u
    xs = []
    for i in range(m_u):
        xs.append((i + rng.uniform()) / m_u)
    for i in range(m_b):
        u = (i + rng.uniform()) / m_b
        xs.append(clamp01(mu + sigma * norm_quantile(u)))
    return xs


def nearest(x, n):
    # round half away from zero? Rust f64::round rounds half away from zero;
    # python round() is banker's. Use floor(x*(n-1)+0.5).
    j = int(math.floor(min(max(x, 0.0), 1.0) * (n - 1) + 0.5))
    return min(j, n - 1)


def census_1d(xs, n, bounds):
    p = len(bounds) - 1
    c = [0] * p
    for x in xs:
        g = nearest(x, n)
        # owner
        lo = 0
        for i in range(p):
            if bounds[i] <= g < bounds[i + 1]:
                c[i] += 1
                break
        else:
            c[p - 1] += 1
    return c


def from_targets(n, grid_sorted, targets):
    p = len(targets)
    m = len(grid_sorted)
    assert sum(targets) == m

    def count_below(b):
        # partition_point: first index with g >= b
        import bisect
        return bisect.bisect_left(grid_sorted, b)

    bounds = [0]
    cum = 0
    for i, t in enumerate(targets[:p - 1]):
        cum += t
        remaining = p - 1 - i
        lo = bounds[i] + 1
        hi = n - remaining
        if cum == 0:
            b = lo
        elif cum >= m:
            b = hi
        else:
            u = grid_sorted[cum - 1]
            v = grid_sorted[cum]
            if u < v:
                b = u + 1 + (v - 1 - u) // 2
            else:
                below = count_below(u)
                above = count_below(u + 1)
                if abs(cum - below) <= abs(cum - above):
                    b = u
                else:
                    b = u + 1
        b = min(max(b, lo), hi)
        bounds.append(b)
    bounds.append(n)
    return bounds


def balance_ratio(c):
    if not c:
        return 1.0
    mx = max(c)
    if mx == 0:
        return 0.0
    return min(c) / mx


def cycle_rng(seed, k):
    """Port of harness::cycles::cycle_rng — Rng::new(seed).fork(k)."""
    base = Rng(seed)
    return Rng(base.next_u64() ^ ((k * 0x9E3779B97F4A7C15) & M64))


def simulate_1d(n, p, m, K, tau, seed, mu0, path, sigma, policy):
    bounds = [i * n // p for i in range(p + 1)]
    rows = []
    for k in range(K):
        t = 0.0 if K <= 1 else k / (K - 1)
        rng = cycle_rng(seed, k)
        xs = drift_blob_1d(m, t, rng, mu0, path, sigma)
        cen = census_1d(xs, n, bounds)
        bal_before = balance_ratio(cen)
        if policy == 'never':
            reb = False
        elif policy == 'every':
            reb = True
        else:
            reb = bal_before < tau
        if reb:
            grid = sorted(nearest(x, n) for x in xs)
            targets = [m // p] * p
            for i in range(m % p):
                targets[i] += 1
            bounds = from_targets(n, grid, targets)
            cen = census_1d(xs, n, bounds)
        bal_after = balance_ratio(cen)
        rows.append((k, round(bal_before, 3), round(bal_after, 3), reb))
    return rows


# ---------------- 2D ----------------

GOLDEN = 0.6180339887498949


def drift_blob_2d(m, t, rng, c0, path, sigma):
    cx = c0[0] + path[0] * t
    cy = c0[1] + path[1] * t
    m_u = m // 2
    m_b = m - m_u
    pts = []
    for i in range(m_u):
        x = (i + rng.uniform()) / m_u
        y = (i * GOLDEN + rng.uniform() / m_u) % 1.0
        pts.append((x, y))
    for i in range(m_b):
        u = (i + rng.uniform()) / m_b
        r = sigma * math.sqrt(-2.0 * math.log(1.0 - u))
        th = 2.0 * math.pi * ((i * GOLDEN + (rng.uniform() - 0.5) / m_b) % 1.0)
        pts.append((clamp01(cx + r * math.cos(th)), clamp01(cy + r * math.sin(th))))
    return pts


def census_2d(pts, n, xbounds, ybounds):
    px = len(xbounds) - 1
    py = len(ybounds[0]) - 1
    c = [0] * (px * py)
    for (x, y) in pts:
        ix = nearest(x, n)
        iy = nearest(y, n)
        bx = 0
        for i in range(px):
            if xbounds[i] <= ix < xbounds[i + 1]:
                bx = i
                break
        else:
            bx = px - 1
        yb = ybounds[bx]
        by = 0
        for j in range(py):
            if yb[j] <= iy < yb[j + 1]:
                by = j
                break
        else:
            by = py - 1
        c[by * px + bx] += 1
    return c


def apportion(template, m):
    p = len(template)
    total = sum(template)
    if total == 0:
        out = [m // p] * p
        for i in range(m % p):
            out[i] += 1
        return out
    out = [t * m // total for t in template]
    assigned = sum(out)
    rem = sorted(((t * m) % total, i) for i, t in enumerate(template))
    rem = sorted(rem, key=lambda x: (-x[0], x[1]))
    for _, i in rem[:m - assigned]:
        out[i] += 1
    return out


def rebalance_2d(pts, n, px, py, targets):
    # grid indices sorted by (x, y) float coords like ObservationSet2d
    pts_sorted = sorted(pts, key=lambda q: (q[0], q[1]))
    grid = [(nearest(x, n), nearest(y, n)) for (x, y) in pts_sorted]
    gx = [g[0] for g in grid]
    # NOTE: gx may not be perfectly non-decreasing when two x coords on
    # opposite sides of a midpoint round differently -- actually sorting by
    # float x and rounding preserves non-decreasing gx. fine.
    col_targets = [sum(targets[by * px + bx] for by in range(py)) for bx in range(px)]
    gx_sorted = sorted(gx)
    xbounds = from_targets(n, gx_sorted, col_targets)
    import bisect
    ybounds = []
    for bx in range(px):
        lo, hi = xbounds[bx], xbounds[bx + 1]
        a = bisect.bisect_left(gx, lo)
        b = bisect.bisect_left(gx, hi)
        ys = sorted(g[1] for g in grid[a:b])
        template = [targets[by * px + bx] for by in range(py)]
        row_targets = apportion(template, len(ys))
        col_bounds = from_targets(n, ys, row_targets)
        ybounds.append(col_bounds)
    return xbounds, ybounds


def simulate_2d(n, px, py, m, K, tau, seed, c0, path, sigma, policy):
    xbounds = [i * n // px for i in range(px + 1)]
    ycol = [j * n // py for j in range(py + 1)]
    ybounds = [list(ycol) for _ in range(px)]
    p = px * py
    rows = []
    for k in range(K):
        t = 0.0 if K <= 1 else k / (K - 1)
        rng = cycle_rng(seed, k)
        pts = drift_blob_2d(m, t, rng, c0, path, sigma)
        cen = census_2d(pts, n, xbounds, ybounds)
        bal_before = balance_ratio(cen)
        if policy == 'never':
            reb = False
        elif policy == 'every':
            reb = True
        else:
            reb = bal_before < tau
        if reb:
            targets = [m // p] * p
            for i in range(m % p):
                targets[i] += 1
            xbounds, ybounds = rebalance_2d(pts, n, px, py, targets)
            cen = census_2d(pts, n, xbounds, ybounds)
        bal_after = balance_ratio(cen)
        rows.append((k, round(bal_before, 3), round(bal_after, 3), reb))
    return rows


if __name__ == '__main__':
    # The shipped acceptance-scenario constants (see DriftLayout::TranslatingBlob).
    n, p, m, K = 512, 4, 800, 8
    tau = 0.9
    mu0, path, sigma = 0.28, 0.06, 0.16
    for seed in [42, 7, 123]:
        print(f"--- 1D seed={seed} n={n} p={p} m={m} tau={tau} mu0={mu0} path={path} sigma={sigma}")
        for pol in ['threshold', 'every', 'never']:
            rows = simulate_1d(n, p, m, K, tau, seed, mu0, path, sigma, pol)
            rebs = sum(1 for r in rows if r[3])
            print(f"  {pol:10s} rebs={rebs} end={rows[-1][2]:.3f} rows={rows}")
