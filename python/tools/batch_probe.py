#!/usr/bin/env python3
"""Seed the repo-root `BENCH_batch.json` with *measured* timings when no
Rust toolchain is available.

Timed port of the A10 ablation in `rust/benches/ablations.rs`: the
many-small-blocks cell (64² grid, p=8 as a 4x2 box partition, dense
local backend) solved over warm Retain ticks with per-block vs batched
dispatch. The problem family comes from `scaling_probe`; the
shape-bucket ladder (powers of two plus 1.5x midpoints from 8) is a
faithful port of `linalg::batch::bucket`, and the pad-waste field
reports the bucket-slab storage overhead of the arena exactly as
`linalg::batch::pad_waste` defines it.

A warm tick applies every block's cached factor (here the explicit gram
inverse, identical for both paths) phase by phase on an 8-thread worker
pool — numpy releases the GIL inside BLAS, so the pool genuinely
parallelises and per-job dispatch cost is measured, as in the Rust
`WorkerPool` cell. The per-block path submits one job per block and
allocates fresh rhs/solution buffers every solve (what the per-block
coordinator path does); the batched path submits one job per shape
group and stages into persistent arena stacks through `out=` views —
the same per-member BLAS operations, fewer dispatches, zero per-solve
allocation.

The authoritative bitwise contract lives on the Rust side (A10 gate,
`rust/tests/batch.rs`); here the per-member analyses are compared and
reported in `analysis_max_abs_diff`.

`cargo xtask bench-refresh` (the CI bench job) overwrites this document
with Rust A10 measurements; the schema matches that emitter.

Run: python3 python/tools/batch_probe.py  (writes BENCH_batch.json at
the repo root)
"""

import json
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np

from scaling_probe import build_problem, extract_blocks

GRID = 64
PX, PY = 4, 2
P = PX * PY
SEED = 7
OBS_PER_AXIS = 8
WARM_TICKS = 20
WORKERS = 8


def bucket(d):
    """Port of `linalg::batch::bucket`: powers of two + 1.5x midpoints."""
    if d == 0:
        return 0
    b = 8
    while True:
        if d <= b:
            return b
        if d <= b + b // 2:
            return b + b // 2
        b *= 2


def setup():
    """Extract + factor every block once (the cold epoch); group each
    phase's members by bucketed shape, as `plan_batches` does."""
    rows = build_problem(GRID, OBS_PER_AXIS * GRID, SEED)
    blocks = extract_blocks(rows, GRID, PX, PY)
    members = []
    for blk in blocks:
        a = blk["a"].toarray()
        at_w = a.T * blk["w"]
        g = at_w @ a
        members.append({
            "phase": blk["phase"],
            "at_w": at_w,
            "ginv": np.linalg.inv(g),
            "b": blk["y"],
            "n": a.shape[1],
            "m": a.shape[0],
        })
    phases = sorted({m["phase"] for m in members})
    groups = []
    for ph in phases:
        by_shape = {}
        for mi, m in enumerate(members):
            if m["phase"] != ph:
                continue
            key = (bucket(m["n"]), bucket(m["m"]))
            by_shape.setdefault(key, []).append(mi)
        for key, mem in sorted(by_shape.items()):
            groups.append((key, mem))
    return members, phases, groups


def pad_waste(members, groups):
    padded = sum(np * mp * len(mem) for (np, mp), mem in groups)
    used = sum(members[i]["n"] * members[i]["m"] for _, mem in groups for i in mem)
    return 1.0 - used / padded if padded else 0.0


def make_arena(members, groups):
    """Persistent rhs/solution stacks per group (the workspace arena):
    allocated once at pack time, refilled in place every tick."""
    arena = []
    for (_, mem) in groups:
        n_max = max(members[i]["n"] for i in mem)
        arena.append((np.empty((len(mem), n_max)), np.empty((len(mem), n_max))))
    return arena


def tick_per_block(pool, members, by_phase):
    """One warm tick, per-block dispatch: one pooled job per block, each
    solve allocating its own rhs and solution buffers."""
    def job(m):
        rhs = m["at_w"] @ m["b"]
        return m["ginv"] @ rhs

    out = [None] * len(members)
    for ph, mids in by_phase:
        futs = [(mi, pool.submit(job, members[mi])) for mi in mids]
        for mi, f in futs:
            out[mi] = f.result()
    return out


def tick_batched(pool, members, groups, arena, phase_groups):
    """One warm tick, batched dispatch: one pooled job per shape group,
    staging into the group's arena stacks through `out=` views — the
    same per-member BLAS calls with zero per-solve allocation."""
    def job(gi):
        _, mem = groups[gi]
        rhs_buf, x_buf = arena[gi]
        for i, mi in enumerate(mem):
            m = members[mi]
            n = m["n"]
            np.dot(m["at_w"], m["b"], out=rhs_buf[i, :n])
            np.dot(m["ginv"], rhs_buf[i, :n], out=x_buf[i, :n])
        return gi

    out = [None] * len(members)
    for ph, gids in phase_groups:
        futs = [pool.submit(job, gi) for gi in gids]
        for f in futs:
            gi = f.result()
            _, mem = groups[gi]
            _, x_buf = arena[gi]
            for i, mi in enumerate(mem):
                out[mi] = x_buf[i, : members[mi]["n"]]
    return out


def main():
    members, phases, groups = setup()
    arena = make_arena(members, groups)
    by_phase = [(ph, [mi for mi, m in enumerate(members) if m["phase"] == ph])
                for ph in phases]
    phase_groups = [(ph, [gi for gi, (_, mem) in enumerate(groups)
                          if members[mem[0]]["phase"] == ph])
                    for ph in phases]
    pool = ThreadPoolExecutor(max_workers=WORKERS)

    # Alternate the two modes across rounds and keep each mode's best
    # round: decorrelates scheduler/thermal drift from the comparison.
    rounds = 5
    t_per, t_bat = np.inf, np.inf
    x_per = x_bat = None
    tick_per_block(pool, members, by_phase)  # pool warm-up
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(WARM_TICKS):
            x_per = tick_per_block(pool, members, by_phase)
        t_per = min(t_per, (time.perf_counter() - t0) / WARM_TICKS)
        t0 = time.perf_counter()
        for _ in range(WARM_TICKS):
            x_bat = tick_batched(pool, members, groups, arena, phase_groups)
        t_bat = min(t_bat, (time.perf_counter() - t0) / WARM_TICKS)
    pool.shutdown()

    diff = max(float(np.max(np.abs(a - b))) for a, b in zip(x_per, x_bat))
    bitwise = all(np.array_equal(a, b) for a, b in zip(x_per, x_bat))
    speedup = t_per / max(t_bat, 1e-12)
    waste = pad_waste(members, groups)
    g_per = 1.0  # Off mode: one dispatch group per phase.
    g_bat = len(groups) / len(phases)
    print(f"per-block: {t_per * 1e3:.3f}ms/tick   "
          f"batched: {t_bat * 1e3:.3f}ms/tick   speedup {speedup:.2f}x")
    print(f"groups/phase {g_bat:.2f}  pad_waste {waste:.3f}  "
          f"max|Δx| {diff:.1e}  bitwise={bitwise}")
    doc = {
        "bench": "batch",
        "measured": True,
        "scenario": {
            "dim": 2, "grid": GRID, "p": P, "backend": "dense",
            "warm_ticks": WARM_TICKS, "seed": SEED,
        },
        "warm_tick_per_block_s": round(t_per, 6),
        "warm_tick_batched_s": round(t_bat, 6),
        "speedup": round(speedup, 4),
        "groups_per_phase_per_block": g_per,
        "groups_per_phase_batched": round(g_bat, 4),
        "pad_waste": round(waste, 6),
        "analysis_max_abs_diff": diff,
        "bitwise_batch_ok": bool(bitwise),
        "note": ("seed baseline measured by python/tools/batch_probe.py — "
                 "a timed single-process port of the A10 cell (pooled "
                 "group-wise dispatch with arena-resident scratch vs a "
                 "per-block job per solve with fresh buffers, 8 worker "
                 "threads, identical per-member BLAS calls). The bitwise "
                 "batched-vs-per-block contract is enforced by the Rust "
                 "A10 gate and rust/tests/batch.rs; `cargo xtask "
                 "bench-refresh` replaces this document with Rust "
                 "measurements."),
        "source": "python/tools/batch_probe.py",
    }
    out = Path(__file__).resolve().parents[2] / "BENCH_batch.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
