#!/usr/bin/env python3
"""Exact-arithmetic mirror of `cargo xtask lint` (xtask/src/{lex,rules}.rs).

No Rust toolchain exists in the authoring container, so the lint's scanner
and all seven rules are ported line-for-line here and run against the real
tree plus the fixture corpus; CI then re-runs the Rust implementation.
Keep in sync with xtask when adding rules.

Run:  python3 python/tools/lint_mirror.py            # lint rust/src/**
      python3 python/tools/lint_mirror.py --check-fixtures
"""
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))

NO_PARTIAL_CMP = "no-partial-cmp-on-records"
NO_WALL_CLOCK = "no-wall-clock-in-sim"
NO_DENSE_ALLOC = "no-dense-alloc-on-sparse-path"
NO_UNWRAP = "no-unwrap-in-lib"
GEOMETRY_REGISTRATION = "geometry-registration"
NO_SWEEP_ALLOC = "no-alloc-in-sweep-loop"
NO_GLOBAL_BROADCAST = "no-global-broadcast-in-phase-loop"
WAIVER_SYNTAX = "waiver-syntax"
RULES = [
    NO_PARTIAL_CMP,
    NO_WALL_CLOCK,
    NO_DENSE_ALLOC,
    NO_UNWRAP,
    GEOMETRY_REGISTRATION,
    NO_SWEEP_ALLOC,
    NO_GLOBAL_BROADCAST,
]

WALL_CLOCK_ALLOWED = ["rust/src/util/timer.rs", "rust/src/dydd/", "rust/src/coordinator/"]
SPARSE_PATH = ["rust/src/linalg/sparse.rs", "rust/src/ddkf/local.rs", "rust/src/stream/"]
SWEEP_HOT_FILES = ["rust/src/ddkf/schwarz.rs", "rust/src/coordinator/worker.rs"]
PHASE_HOT_FILES = ["rust/src/coordinator/leader.rs"]


class Line:
    def __init__(self):
        self.code = []
        self.comment = []
        self.in_test = False
        self.in_hot = False
        self.in_phase = False


class SourceFile:
    def __init__(self, path, lines, waivers, bad_waivers):
        self.path = path
        self.lines = lines
        self.waivers = waivers  # (rule, reason, file_scoped, at, target)
        self.bad_waivers = bad_waivers  # (at, why)

    def waived(self, rule, line):
        return any(
            w[0] == rule and (w[2] or w[4] == line) for w in self.waivers
        )


def is_ident(c):
    return c.isalnum() or c == "_"


def literal_prefix(chars, i):
    c = chars[i]
    if c == '"':
        return (1, 0, False)
    if c in ("r", "b"):
        j = i + 1
        if c == "b" and j < len(chars) and chars[j] == '"':
            return (2, 0, False)
        if c == "b":
            if j >= len(chars) or chars[j] != "r":
                return None
            j += 1
        hashes = 0
        while j < len(chars) and chars[j] == "#":
            hashes += 1
            j += 1
        if j < len(chars) and chars[j] == '"':
            return (j + 1 - i, hashes, True)
    return None


def is_char_literal(chars, i):
    if i + 1 >= len(chars):
        return False
    nxt = chars[i + 1]
    if nxt == "\\":
        return True
    if is_ident(nxt):
        return i + 2 < len(chars) and chars[i + 2] == "'"
    return True


def scan(path, src):
    chars = list(src)
    lines = []
    cur = Line()
    mode = "code"
    hashes = 0
    depth = 0
    i = 0
    n = len(chars)
    while i < n:
        c = chars[i]
        if c == "\n":
            lines.append(cur)
            cur = Line()
            i += 1
            continue
        if mode == "code":
            prev_ident = i > 0 and is_ident(chars[i - 1])
            lit = None if prev_ident else literal_prefix(chars, i)
            if c == "/" and i + 1 < n and chars[i + 1] == "/":
                i += 2
                while i < n and chars[i] != "\n":
                    cur.comment.append(chars[i])
                    i += 1
            elif c == "/" and i + 1 < n and chars[i + 1] == "*":
                mode, depth = "block", 1
                i += 2
            elif lit is not None:
                adv, hashes, raw = lit
                cur.code.append('"')
                mode = "rawstr" if raw else "str"
                i += adv
            elif c == "'":
                cur.code.append("'")
                if is_char_literal(chars, i):
                    mode = "chr"
                i += 1
            else:
                cur.code.append(c)
                i += 1
        elif mode == "str":
            if c == "\\":
                if i + 1 < n and chars[i + 1] == "\n":
                    lines.append(cur)
                    cur = Line()
                i += 2
            elif c == '"':
                cur.code.append('"')
                mode = "code"
                i += 1
            else:
                i += 1
        elif mode == "rawstr":
            tail = chars[i + 1 : i + 1 + hashes]
            if c == '"' and len(tail) >= hashes and all(h == "#" for h in tail):
                cur.code.append('"')
                mode = "code"
                i += 1 + hashes
            else:
                i += 1
        elif mode == "chr":
            if c == "\\":
                i += 2
            elif c == "'":
                cur.code.append("'")
                mode = "code"
                i += 1
            else:
                i += 1
        else:  # block comment
            if c == "*" and i + 1 < n and chars[i + 1] == "/":
                depth -= 1
                mode = "code" if depth == 0 else "block"
                i += 2
            elif c == "/" and i + 1 < n and chars[i + 1] == "*":
                depth += 1
                i += 2
            else:
                cur.comment.append(c)
                i += 1
    if cur.code or cur.comment:
        lines.append(cur)
    for ln in lines:
        ln.code = "".join(ln.code)
        ln.comment = "".join(ln.comment)
    mark_test_regions(lines)
    mark_hot_regions(lines)
    mark_phase_regions(lines)
    waivers, bad = collect_waivers(lines)
    return SourceFile(path, lines, waivers, bad)


def mark_test_regions(lines):
    depth = 0
    close_at = []
    pending = False
    for line in lines:
        code = line.code
        if "#[cfg(test)]" in code or "#[cfg(all(test" in code or "#[test]" in code:
            pending = True
        in_test = bool(close_at)
        for c in code:
            if c == "{":
                depth += 1
                if pending:
                    close_at.append(depth)
                    pending = False
                    in_test = True
            elif c == "}":
                if close_at and close_at[-1] == depth:
                    close_at.pop()
                depth -= 1
        line.in_test = in_test or bool(close_at)


def mark_hot_regions(lines):
    # lint:sweep-hot-start … lint:sweep-hot-end comment markers, inclusive.
    hot = False
    for line in lines:
        if "lint:sweep-hot-start" in line.comment:
            hot = True
        line.in_hot = hot
        if "lint:sweep-hot-end" in line.comment:
            hot = False


def mark_phase_regions(lines):
    # lint:phase-hot-start … lint:phase-hot-end comment markers, inclusive.
    hot = False
    for line in lines:
        if "lint:phase-hot-start" in line.comment:
            hot = True
        line.in_phase = hot
        if "lint:phase-hot-end" in line.comment:
            hot = False


def collect_waivers(lines):
    waivers, bad = [], []
    for at, line in enumerate(lines):
        comment = line.comment
        pos = comment.find("lint:allow")
        if pos < 0:
            continue
        rest = comment[pos + len("lint:allow") :]
        file_scoped = rest.startswith("-file")
        if file_scoped:
            rest = rest[len("-file") :]
        if not rest.startswith("("):
            bad.append((at, "expected `(` after lint:allow"))
            continue
        rest = rest[1:]
        close = rest.find(")")
        if close < 0:
            bad.append((at, "unclosed `(` in lint:allow"))
            continue
        rule = rest[:close].strip()
        reason = rest[close + 1 :].strip()
        if not reason:
            bad.append((at, f"waiver for `{rule}` has no reason"))
            continue
        target = at
        if not line.code.strip():
            for j in range(at + 1, len(lines)):
                if lines[j].code.strip():
                    target = j
                    break
        waivers.append((rule, reason, file_scoped, at, target))
    return waivers, bad


def token_positions(code, tok):
    out = []
    start = 0
    while True:
        at = code.find(tok, start)
        if at < 0:
            return out
        before_ok = at == 0 or not is_ident(code[at - 1])
        end = at + len(tok)
        after_ok = end >= len(code) or not is_ident(code[end])
        if before_ok and after_ok:
            out.append(at)
        start = at + len(tok)


def has_token(code, tok):
    return bool(token_positions(code, tok))


def has_token_seq(code, tok):
    start = 0
    while True:
        at = code.find(tok, start)
        if at < 0:
            return False
        if at == 0 or not is_ident(code[at - 1]):
            return True
        start = at + len(tok)


def geometry_impls(code):
    names = []
    if "impl" not in code:
        return names
    for trait_name in ["Geometry", "RecordGeometry"]:
        for at in token_positions(code, trait_name):
            rest = code[at + len(trait_name) :]
            if not rest.startswith(" for "):
                continue
            rest = rest[len(" for ") :]
            name = ""
            for c in rest:
                if is_ident(c):
                    name += c
                else:
                    break
            if name:
                names.append(name)
    return names


def lint_file(sf):
    out = []
    for at, why in sf.bad_waivers:
        out.append((sf.path, at + 1, WAIVER_SYNTAX, why))
    for rule, _, _, at, _ in sf.waivers:
        if rule not in RULES:
            out.append((sf.path, at + 1, WAIVER_SYNTAX, f"unknown rule `{rule}`"))
    wall_clock_scoped = not any(sf.path.startswith(p) for p in WALL_CLOCK_ALLOWED)
    sparse_scoped = any(sf.path.startswith(p) for p in SPARSE_PATH)
    unwrap_scoped = sf.path != "rust/src/main.rs"
    sweep_scoped = sf.path in SWEEP_HOT_FILES
    phase_scoped = sf.path in PHASE_HOT_FILES
    for idx, line in enumerate(sf.lines):
        if line.in_test:
            continue
        code = line.code

        def flag(rule, msg):
            if not sf.waived(rule, idx):
                out.append((sf.path, idx + 1, rule, msg))

        if has_token(code, "partial_cmp"):
            flag(NO_PARTIAL_CMP, "partial_cmp breaks on NaN — total_cmp/f64_key")
        if wall_clock_scoped:
            for tok in ["Instant", "SystemTime"]:
                if has_token(code, tok):
                    flag(NO_WALL_CLOCK, f"{tok} outside util::timer/dydd/coordinator")
        if sparse_scoped:
            for tok in ["Mat::zeros", "Mat::identity"]:
                if has_token_seq(code, tok):
                    flag(NO_DENSE_ALLOC, f"{tok} on the sparse path")
        if sweep_scoped and line.in_hot:
            for tok in ["Vec::new", "vec!", "Mat::zeros"]:
                if has_token_seq(code, tok):
                    flag(NO_SWEEP_ALLOC, f"{tok} inside a sweep hot region")
        if phase_scoped and line.in_phase and has_token_seq(code, "Arc::new"):
            flag(NO_GLOBAL_BROADCAST, "Arc::new inside the phase dispatch loop")
        if unwrap_scoped:
            if ".unwrap()" in code:
                flag(NO_UNWRAP, "unwrap() on a library path")
            if has_token_seq(code, "panic!"):
                flag(NO_UNWRAP, "panic! on a library path")
    return out


def lint_geometry_registration(files, registry, golden):
    out = []
    for sf in files:
        for idx, line in enumerate(sf.lines):
            if line.in_test:
                continue
            for name in geometry_impls(line.code):
                if sf.waived(GEOMETRY_REGISTRATION, idx):
                    continue
                if name not in registry:
                    out.append(
                        (sf.path, idx + 1, GEOMETRY_REGISTRATION, f"`{name}` not in registry")
                    )
                if name not in golden:
                    out.append(
                        (sf.path, idx + 1, GEOMETRY_REGISTRATION, f"`{name}` not golden-covered")
                    )
    return out


def walk(d):
    out = []
    for base, dirs, names in os.walk(d):
        dirs.sort()
        for name in sorted(names):
            if name.endswith(".rs"):
                out.append(os.path.join(base, name))
    return out


def read(p):
    with open(p, encoding="utf-8") as f:
        return f.read()


def lint_tree():
    files = []
    for p in walk(os.path.join(ROOT, "rust", "src")):
        rel = os.path.relpath(p, ROOT).replace(os.sep, "/")
        files.append(scan(rel, read(p)))
    registry = read(os.path.join(ROOT, "rust/src/decomp/registry.rs"))
    golden = read(os.path.join(ROOT, "rust/tests/decomp_golden.rs"))
    findings = []
    for sf in files:
        findings.extend(lint_file(sf))
    findings.extend(lint_geometry_registration(files, registry, golden))
    for path, ln, rule, msg in findings:
        print(f"{path}:{ln}: [{rule}] {msg}")
    print(f"lint mirror: {len(findings)} finding(s) in {len(files)} files")
    return 1 if findings else 0


def fixture_path(text):
    at = text.find("lint:fixture-path(")
    if at < 0:
        return "rust/src/fixture.rs"
    rest = text[at + len("lint:fixture-path(") :]
    end = rest.find(")")
    return rest[:end].strip() if end >= 0 else "rust/src/fixture.rs"


def check_fixtures():
    registry = read(os.path.join(ROOT, "rust/src/decomp/registry.rs"))
    golden = read(os.path.join(ROOT, "rust/tests/decomp_golden.rs"))
    failures = checked = 0
    for p in walk(os.path.join(ROOT, "xtask", "fixtures")):
        name = os.path.basename(p)
        if name.endswith(".violate.rs"):
            expect = name[: -len(".violate.rs")]
        elif name.endswith(".ok.rs"):
            expect = None
        else:
            print(f"SKIP {name}")
            continue
        text = read(p)
        sf = scan(fixture_path(text), text)
        findings = lint_file(sf)
        findings.extend(lint_geometry_registration([sf], registry, golden))
        checked += 1
        rules_hit = {f[2] for f in findings}
        if expect is None:
            ok = not findings
            why = f"expected clean, got {len(findings)}"
        else:
            ok = bool(findings) and rules_hit == {expect}
            why = f"expected only `{expect}`, got {sorted(rules_hit)}"
        if ok:
            print(f"ok   {name}")
        else:
            print(f"FAIL {name}: {why}")
            for f in findings:
                print(f"     {f[0]}:{f[1]}: [{f[2]}] {f[3]}")
            failures += 1
    print(f"lint mirror --check-fixtures: {checked} fixtures, {failures} failure(s)")
    return 1 if failures or not checked else 0


if __name__ == "__main__":
    if "--check-fixtures" in sys.argv[1:]:
        sys.exit(check_fixtures())
    sys.exit(lint_tree())
