#!/usr/bin/env python3
"""Mirror of rust/src/coordinator/{protocol,model}.rs — the exhaustive
interleaving checker for the leader/worker protocol replica.

The container building this repo has no Rust toolchain, so (as with
cycle_census_sim.py and friends) the Rust logic is validated by running an
exact Python port of it. Keep this file in lock-step with the Rust
checker: same states, same enabled-action rule, same verdicts. Run:

    python3 python/tools/protocol_model_sim.py
"""

from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---- protocol.rs -----------------------------------------------------------

SETUP, REFRESHB, RETAIN, SOLVE, SHUTDOWN = "Setup", "RefreshB", "Retain", "Solve", "Shutdown"
SOLVE_RESTRICTED, SOLVE_DELTA = "SolveRestricted", "SolveDelta"
READY, SOLUTION, FAILED = "Ready", "Solution", "Failed"


class WorkerModel:
    def __init__(self, wid):
        self.id = wid
        self.epoch = None
        # A read-set snapshot is standing (SolveRestricted since the last
        # epoch dispatch). The real worker would accept a premature delta
        # against a zeroed snapshot; the replica rejects it instead, so
        # the checkers prove the leader never sends one.
        self.snapshot = False
        self.stopped = False

    def key(self):
        return (self.id, self.epoch, self.snapshot, self.stopped)

    def step(self, req):
        kind, epoch = req
        assert not self.stopped, "message delivered to a stopped worker"
        if kind == SETUP:
            self.epoch = epoch
            self.snapshot = False
            return (READY, self.id, None)
        if kind in (REFRESHB, RETAIN):
            if self.epoch is not None:
                self.snapshot = False
                return (READY, self.id, None)
            self.stopped = True
            return (FAILED, self.id, None)
        if kind == SOLVE:
            if self.epoch is not None:
                return (SOLUTION, self.id, self.epoch)
            self.stopped = True
            return (FAILED, self.id, None)
        if kind == SOLVE_RESTRICTED:
            if self.epoch is not None:
                self.snapshot = True
                return (SOLUTION, self.id, self.epoch)
            self.stopped = True
            return (FAILED, self.id, None)
        if kind == SOLVE_DELTA:
            if self.epoch is not None and self.snapshot:
                return (SOLUTION, self.id, self.epoch)
            self.stopped = True
            return (FAILED, self.id, None)
        if kind == SHUTDOWN:
            self.stopped = True
            return None
        raise AssertionError(kind)


class LeaderCache:
    def __init__(self, p):
        self.epochs = [None] * p

    def key(self):
        return tuple(self.epochs)

    def admit(self, worker, task):
        kind, epoch = task
        if kind == SETUP:
            self.epochs[worker] = epoch
            return None
        if kind in (REFRESHB, RETAIN):
            if self.epochs[worker] is None:
                return f"RefreshB/Retain for uncached block {worker}"
            if self.epochs[worker] != epoch:
                return f"block {worker}: cached epoch desync"
        return None


# ---- model.rs --------------------------------------------------------------

ASSEMBLE, SOLVE_DEATH, DELTA_DEATH = "Assemble", "SolveDeath", "DeltaDeath"
COMPLETED, DIAGNOSED = "Completed", "Diagnosed"


@dataclass
class Scenario:
    p: int
    epochs: list  # [(tasks, phases, delta)]
    death: Optional[Tuple[int, str]] = None


class Sim:
    def __init__(self, sc):
        self.workers = [WorkerModel(w) for w in range(sc.p)]
        self.alive = [True] * sc.p
        self.inbox = [deque() for _ in range(sc.p)]
        self.outbox = [deque() for _ in range(sc.p)]
        self.cache = LeaderCache(sc.p)
        # Leader-side delta bookkeeping (`sent_stamp` in the real leader):
        # reset at every epoch dispatch, exactly as the change tracker is
        # per solve call.
        self.snap_sent = [False] * sc.p
        self.leader = ("Dispatch", 0)
        self.advance_leader(sc)

    def key(self):
        return (
            tuple(w.key() for w in self.workers),
            tuple(self.alive),
            tuple(tuple(q) for q in self.inbox),
            tuple(tuple(q) for q in self.outbox),
            self.cache.key(),
            tuple(self.snap_sent),
            self.leader,
        )

    def clone(self, sc):
        other = Sim.__new__(Sim)
        other.workers = []
        for w in self.workers:
            nw = WorkerModel(w.id)
            nw.epoch, nw.snapshot, nw.stopped = w.epoch, w.snapshot, w.stopped
            other.workers.append(nw)
        other.alive = list(self.alive)
        other.inbox = [deque(q) for q in self.inbox]
        other.outbox = [deque(q) for q in self.outbox]
        other.cache = LeaderCache(len(self.alive))
        other.cache.epochs = list(self.cache.epochs)
        other.snap_sent = list(self.snap_sent)
        other.leader = self.leader
        return other

    def finished(self, w):
        return not self.alive[w] or self.workers[w].stopped

    def end(self, verdict):
        for w in range(len(self.workers)):
            if self.alive[w] and not self.workers[w].stopped:
                self.inbox[w].append((SHUTDOWN, None))
        self.leader = ("Ended", verdict)

    def advance_leader(self, sc):
        while True:
            state = self.leader
            if state[0] == "Dispatch":
                epoch = state[1]
                tasks, _phases, _delta = sc.epochs[epoch]
                # A new epoch starts a fresh change tracker: every block's
                # next solve must re-ship its full read set.
                self.snap_sent = [False] * len(self.workers)
                for w, task in enumerate(tasks):
                    if self.cache.admit(w, task) is not None or not self.alive[w]:
                        self.end(DIAGNOSED)
                        return
                    self.inbox[w].append(task)
                self.leader = ("AwaitReady", epoch, len(tasks))
                return
            if state[0] == "SendPhase":
                epoch, phase = state[1], state[2]
                _tasks, phases, delta = sc.epochs[epoch]
                if phase == len(phases):
                    if epoch + 1 == len(sc.epochs):
                        self.end(COMPLETED)
                        return
                    self.leader = ("Dispatch", epoch + 1)
                    continue
                for w in phases[phase]:
                    if not self.alive[w]:
                        self.end(DIAGNOSED)
                        return
                    if not delta:
                        req = (SOLVE, None)
                    elif not self.snap_sent[w]:
                        self.snap_sent[w] = True
                        req = (SOLVE_RESTRICTED, None)
                    else:
                        req = (SOLVE_DELTA, None)
                    self.inbox[w].append(req)
                self.leader = ("AwaitSolutions", epoch, phase, len(phases[phase]))
                return
            return

    def enabled(self, detect):
        acts = []
        for w in range(len(self.workers)):
            if self.alive[w] and not self.workers[w].stopped and self.inbox[w]:
                acts.append(("WorkerStep", w))
        if self.leader[0] in ("AwaitReady", "AwaitSolutions"):
            for w in range(len(self.workers)):
                if self.outbox[w]:
                    acts.append(("LeaderRecv", w))
            drained = all(not q for q in self.outbox)
            if detect and drained and any(self.finished(w) for w in range(len(self.workers))):
                acts.append(("LeaderDetect",))
        return acts

    def apply(self, sc, act):
        if act[0] == "WorkerStep":
            w = act[1]
            req = self.inbox[w].popleft()
            dies = False
            if sc.death is not None:
                victim, point = sc.death
                if point == ASSEMBLE:
                    dies = victim == w and req[0] == SETUP
                elif point == DELTA_DEATH:
                    dies = victim == w and req[0] == SOLVE_DELTA
                else:
                    dies = victim == w and req[0] in (SOLVE, SOLVE_RESTRICTED)
            if dies:
                self.alive[w] = False
                return
            rep = self.workers[w].step(req)
            if rep is not None:
                self.outbox[w].append(rep)
        elif act[0] == "LeaderRecv":
            w = act[1]
            rep = self.outbox[w].popleft()
            kind = rep[0]
            state = self.leader
            if state[0] == "AwaitReady" and kind == READY:
                self.leader = ("AwaitReady", state[1], state[2] - 1)
            elif state[0] == "AwaitSolutions" and kind == SOLUTION:
                _, worker, sol = rep
                assert self.cache.epochs[worker] == sol, f"stale-epoch solution from {worker}"
                self.leader = ("AwaitSolutions", state[1], state[2], state[3] - 1)
            elif kind == FAILED:
                self.end(DIAGNOSED)
            else:
                raise AssertionError(f"protocol violation: {rep} in {state}")
            state = self.leader
            if state[0] == "AwaitReady" and state[2] == 0:
                self.leader = ("SendPhase", state[1], 0)
                self.advance_leader(sc)
            elif state[0] == "AwaitSolutions" and state[3] == 0:
                self.leader = ("SendPhase", state[1], state[2] + 1)
                self.advance_leader(sc)
        else:
            self.end(DIAGNOSED)


def explore(sc, expect, detect):
    for tasks, _, _ in sc.epochs:
        assert len(tasks) == sc.p
    visited = set()
    terminals = 0
    stack = [Sim(sc)]
    while stack:
        sim = stack.pop()
        k = sim.key()
        if k in visited:
            continue
        visited.add(k)
        acts = sim.enabled(detect)
        if not acts:
            if sim.leader[0] == "Ended":
                assert sim.leader[1] == expect, f"verdict {sim.leader[1]} != {expect}"
                for w in range(sc.p):
                    assert sim.finished(w), f"worker {w} still running at quiescence"
                terminals += 1
            else:
                return None, f"deadlock: leader blocked in {sim.leader}"
            continue
        for act in acts:
            nxt = sim.clone(sc)
            nxt.apply(sc, act)
            stack.append(nxt)
    return (len(visited), terminals), None


def check(sc, expect):
    stats, err = explore(sc, expect, True)
    assert err is None, err
    return stats


def setup_tasks(p, epoch):
    return [(SETUP, epoch)] * p


def main():
    # Mirrors of the Rust #[test] scenarios, same order.
    for phases in ([[0], [1]], [[0, 1]]):
        stats = check(Scenario(2, [(setup_tasks(2, 0), phases, False)]), COMPLETED)
        assert stats[1] >= 1 and stats[0] > 10, stats
        print(f"solve dispatch {phases}: {stats[0]} states, {stats[1]} terminals")

    sc = Scenario(
        2,
        [
            (setup_tasks(2, 0), [[0], [1]], False),
            ([(RETAIN, 0), (REFRESHB, 0)], [[0], [1]], False),
        ],
    )
    print("epoch reuse:", check(sc, COMPLETED))

    sc = Scenario(
        2,
        [
            (setup_tasks(2, 0), [[0, 1]], False),
            ([(RETAIN, 1), (RETAIN, 0)], [[0, 1]], False),
        ],
    )
    print("epoch desync:", check(sc, DIAGNOSED))

    sc = Scenario(2, [(setup_tasks(2, 0), [[0], [1]], False)], death=(1, ASSEMBLE))
    print("death@assemble:", check(sc, DIAGNOSED))

    for victim in range(2):
        sc = Scenario(2, [(setup_tasks(2, 0), [[0], [1]], False)],
                      death=(victim, SOLVE_DEATH))
        print(f"death@solve victim={victim}:", check(sc, DIAGNOSED))

    # Delta shape: each block's first solve ships the full read set, the
    # second a patch; every-schedule completion proves the
    # restricted-before-delta ordering (the replica rejects premature
    # deltas).
    delta_phases = [[0], [1], [0], [1]]
    sc = Scenario(2, [(setup_tasks(2, 0), delta_phases, True)])
    stats = check(sc, COMPLETED)
    assert stats[1] >= 1 and stats[0] > 10, stats
    print(f"delta dispatch: {stats[0]} states, {stats[1]} terminals")

    # A reused epoch starts a fresh change tracker: its first solve must
    # re-ship the full read set, not open with a delta.
    sc = Scenario(
        2,
        [
            (setup_tasks(2, 0), delta_phases, True),
            ([(RETAIN, 0), (REFRESHB, 0)], delta_phases, True),
        ],
    )
    print("delta epoch reuse:", check(sc, COMPLETED))

    for victim in range(2):
        sc = Scenario(2, [(setup_tasks(2, 0), delta_phases, True)],
                      death=(victim, DELTA_DEATH))
        print(f"death@delta victim={victim}:", check(sc, DIAGNOSED))

    sc = Scenario(2, [(setup_tasks(2, 0), delta_phases, True)],
                  death=(1, DELTA_DEATH))
    stats, err = explore(sc, DIAGNOSED, False)
    assert err is not None and "deadlock" in err, (stats, err)
    print("unacked delta (no detect):", err)

    sc = Scenario(2, [(setup_tasks(2, 0), [[0], [1]], False)], death=(1, SOLVE_DEATH))
    stats, err = explore(sc, DIAGNOSED, False)
    assert err is not None and "deadlock" in err, (stats, err)
    print("old leader (no detect):", err)

    print("protocol model sim: all scenarios pass")


if __name__ == "__main__":
    main()
