#!/usr/bin/env python3
"""Stamp the repo-root `BENCH_cycles.json` with *measured* timings when no
Rust toolchain is available.

Timed port of the A6 cells in `rust/benches/ablations.rs`: the K=8
translating-blob cycle scenario on a 1-D n=512 interval (m=800, p=4)
under the three rebalance policies. Census, trigger and partition
arithmetic come from the integer-exact `cycle_census_sim` port (the same
module that seeded the committed balance numbers), so the `e_*` fields
reproduce the Rust values exactly; the timing fields are real
`time.perf_counter()` measurements of this process: per-cycle block
extraction + dense factorization + multiplicative Schwarz, with the
DyDD repartition timed separately (`rebalance_overhead_fraction` =
ΣT_DyDD / (ΣT_DyDD + ΣT^p_critical), as in `CycleReport`).

Migration volume is the exact 1-D chain flow: Σ over interior edges of
|prefix(census − targets)| — the Σ|δ| of the applied schedule on a path
graph. `cargo xtask bench-refresh` (the CI bench job) overwrites the
document with Rust measurements. The schema matches the A6 emitter
field for field.

Run: python3 python/tools/cycles_probe.py  (writes BENCH_cycles.json at
the repo root)
"""

import json
import time
from pathlib import Path

from cycle_census_sim import (balance_ratio, census_1d, cycle_rng,
                              drift_blob_1d, from_targets, nearest)
from scaling_probe import DenseLocal, schwarz
from stream_probe import extract_block, obs_row, state_rows

N = 512
P = 4
M = 800
CYCLES = 8
SEED = 42
TAU = 0.9
MU0, PATH, SIGMA = 0.28, 0.06, 0.16


def migration_volume(census, targets):
    """Σ|δ| of the minimal path-graph schedule moving `census` to
    `targets`: the absolute prefix flows over interior edges."""
    flow, vol = 0, 0
    for c, t in zip(census[:-1], targets[:-1]):
        flow += c - t
        vol += abs(flow)
    return vol


def run_policy(policy):
    """One K-cycle run under `policy`; returns the summary row fields."""
    bounds = [i * N // P for i in range(P + 1)]
    srows = state_rows(N)
    rebalances = 0
    migrated = 0
    balances = []
    t_dydd_sum = 0.0
    t_crit_sum = 0.0
    t0_total = time.perf_counter()
    for k in range(CYCLES):
        t = 0.0 if CYCLES <= 1 else k / (CYCLES - 1)
        rng = cycle_rng(SEED, k)
        xs = drift_blob_1d(M, t, rng, MU0, PATH, SIGMA)
        cen = census_1d(xs, N, bounds)
        bal_before = balance_ratio(cen)
        if policy == "never":
            reb = False
        elif policy == "every_cycle":
            reb = True
        else:
            reb = bal_before < TAU
        if reb:
            td0 = time.perf_counter()
            targets = [M // P] * P
            for i in range(M % P):
                targets[i] += 1
            migrated += migration_volume(cen, targets)
            grid = sorted(nearest(x, N) for x in xs)
            bounds = from_targets(N, grid, targets)
            t_dydd_sum += time.perf_counter() - td0
            rebalances += 1
        balances.append(balance_ratio(census_1d(xs, N, bounds)))
        rows = srows + [obs_row(x, N, rng.uniform() - 0.5) for x in xs]
        blocks = [extract_block(rows, bounds, bi) for bi in range(P)]
        locals_ = [DenseLocal(b) for b in blocks]
        _, _, t_crit = schwarz(blocks, locals_, N)
        t_crit_sum += t_crit
    wall = time.perf_counter() - t0_total
    overhead = t_dydd_sum / max(t_dydd_sum + t_crit_sum, 1e-12)
    return {
        "policy": policy if policy != "threshold" else f"threshold:{TAU}",
        "rebalances": rebalances,
        "e_final": balances[-1],
        # Left-to-right sum, as the Rust emitter accumulates it (pairwise
        # np.mean differs in the last ulp).
        "e_mean": sum(balances) / len(balances),
        "cycles_per_sec": round(CYCLES / max(wall, 1e-9), 4),
        "rebalance_overhead_fraction": round(overhead, 6),
        "migration_volume": migrated,
    }


def main():
    rows = []
    for policy in ["never", "every_cycle", "threshold"]:
        row = run_policy(policy)
        rows.append(row)
        print(f"{row['policy']:14s} rebs={row['rebalances']} "
              f"e_final={row['e_final']:.3f} e_mean={row['e_mean']:.3f} "
              f"cyc/s={row['cycles_per_sec']:.2f} "
              f"overhead={row['rebalance_overhead_fraction']:.3f} "
              f"moved={row['migration_volume']}")
    doc = {
        "bench": "cycles",
        "measured": True,
        "scenario": {
            "cycles": CYCLES, "dim": 1, "drift": "translating_blob",
            "m": M, "n": N, "p": P, "seed": SEED,
        },
        "policies": rows,
        "note": ("seed baseline measured by python/tools/cycles_probe.py — "
                 "census/balance fields are integer-exact (cycle_census_sim "
                 "arithmetic); timing fields are a single-process port of "
                 "the A6 cycle loop. `cargo xtask bench-refresh` replaces "
                 "this document with Rust measurements."),
        "source": "python/tools/cycles_probe.py",
    }
    out = Path(__file__).resolve().parents[2] / "BENCH_cycles.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
