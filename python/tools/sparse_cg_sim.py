#!/usr/bin/env python3
"""Numerical validation of the rust `linalg::sparse` + `SparseCg` design
(PR 4), exact-ported where it matters. No Rust toolchain exists in the
build container, so the load-bearing numerics are re-derived here:

 1. `pcg` as implemented in rust/src/linalg/sparse.rs (same stopping
    rules: rel-residual tol, 120-iteration stagnation backstop, curvature
    guard, optional warm start) solves regularized weighted normal
    equations to the same solution as a direct solve.
 2. A faithful port of the 2-D CLS local-block Schwarz iteration
    (FivePoint stencil, bilinear obs rows, 2x2 boxes, zero overlap,
    multiplicative sweep, the ConvergenceCheck fp floor): inner CG at
    tol=1e-13 vs inner exact solves must reach outer fixed points within
    1e-8 of each other — the acceptance criterion of the property tests.
 3. The weighted_gram upper-triangle+mirror rewrite is exactly symmetric
    and matches the full accumulation to ~1 ulp.
 4. CG iteration counts stay far below the rust cap (10·n_loc + 200) on
    block sizes up to the 128x128-grid scale of examples/sparse_scaling.

Run: python3 python/tools/sparse_cg_sim.py
"""

import numpy as np

rng = np.random.default_rng(42)


# ---------------------------------------------------------------- pcg port
def pcg(apply_op, rhs, diag_inv, tol, max_iters, x0=None):
    """Line-for-line port of rust `linalg::sparse::pcg` (warm start x0,
    120-iteration stagnation window)."""
    n = len(rhs)
    rhs_norm = np.linalg.norm(rhs)
    if rhs_norm == 0.0:
        return np.zeros(n), 0, True, 0.0
    if x0 is not None:
        x = x0.copy()
        r = rhs - apply_op(x0)
    else:
        x = np.zeros(n)
        r = rhs.copy()
    z = r * diag_inv
    p = z.copy()
    rz = r @ z
    best = np.inf
    since_best = 0
    iters = 0
    while True:
        rel = np.linalg.norm(r) / rhs_norm
        if rel <= tol or iters >= max_iters:
            break
        if rel < best * 0.999:
            best, since_best = rel, 0
        else:
            since_best += 1
            if since_best >= 120:
                break
        q = apply_op(p)
        pq = p @ q
        if pq <= 0.0:
            break
        alpha = rz / pq
        x += alpha * p
        r -= alpha * q
        z = r * diag_inv
        rz_new = r @ z
        beta = rz_new / rz
        p = z + beta * p
        rz = rz_new
        iters += 1
    rel_residual = np.linalg.norm(r) / rhs_norm
    return x, iters, rel_residual <= tol, rel_residual


# ------------------------------------------------- 2-D CLS problem builder
def build_problem2d(n, m_obs, seed):
    """FivePoint{main=1.0, off=0.12} state rows (w0=4) + bilinear obs rows
    (variance 0.01 -> w=100) on an n x n grid, mirroring the rust
    generators' weight structure (values are irrelevant to conditioning,
    so data are random)."""
    r = np.random.default_rng(seed)
    nn = n * n
    rows = []  # (cols, vals, w, y)

    def idx(ix, iy):
        return iy * n + ix

    for iy in range(n):
        for ix in range(n):
            cols, vals = [], []
            if iy > 0:
                cols.append(idx(ix, iy - 1)); vals.append(0.12)
            if ix > 0:
                cols.append(idx(ix - 1, iy)); vals.append(0.12)
            cols.append(idx(ix, iy)); vals.append(1.0)
            if ix + 1 < n:
                cols.append(idx(ix + 1, iy)); vals.append(0.12)
            if iy + 1 < n:
                cols.append(idx(ix, iy + 1)); vals.append(0.12)
            rows.append((cols, vals, 4.0, r.normal()))
    for _ in range(m_obs):
        # gaussian blob at (0.3, 0.35), sigma 0.08, clamped — like the rust
        # GaussianBlob layout
        x = min(max(r.normal(0.3, 0.08), 0.0), 1.0 - 1e-12)
        y = min(max(r.normal(0.35, 0.08), 0.0), 1.0 - 1e-12)
        fx, fy = x * (n - 1), y * (n - 1)
        jx, jy = int(fx), int(fy)
        tx, ty = fx - jx, fy - jy
        cols, vals = [], []
        for (dx, dy, wgt) in [(0, 0, (1 - tx) * (1 - ty)), (1, 0, tx * (1 - ty)),
                              (0, 1, (1 - tx) * ty), (1, 1, tx * ty)]:
            if wgt != 0.0 and jx + dx < n and jy + dy < n:
                cols.append(idx(jx + dx, jy + dy)); vals.append(wgt)
        if cols:
            rows.append((cols, vals, 100.0, r.normal()))
    return rows, nn


def local_blocks_2x2(rows, n):
    """Zero-overlap 2x2 box restriction: per block, the in-set CSR rows and
    the halo couplings (r_loc, global_col, v)."""
    half = n // 2
    boxes = [(0, half, 0, half), (half, n, 0, half), (0, half, half, n), (half, n, half, n)]
    blocks = []
    for (x0, x1, y0, y1) in boxes:
        cols = [iy * n + ix for iy in range(y0, y1) for ix in range(x0, x1)]
        colset = {gc: c for c, gc in enumerate(cols)}
        b_rows, b_w, b_y, halo = [], [], [], []
        for (rcols, rvals, w, y) in rows:
            loc = [(colset[c], v) for c, v in zip(rcols, rvals) if c in colset]
            if not loc:
                continue
            r_loc = len(b_rows)
            b_rows.append(loc)
            b_w.append(w)
            b_y.append(y)
            for c, v in zip(rcols, rvals):
                if c not in colset and v != 0.0:
                    halo.append((r_loc, c, v))
        blocks.append((cols, b_rows, np.array(b_w), np.array(b_y), halo))
    return blocks


def block_dense(block):
    cols, b_rows, w, y, halo = block
    a = np.zeros((len(b_rows), len(cols)))
    for r_loc, loc in enumerate(b_rows):
        for c, v in loc:
            a[r_loc, c] = v
    return a


def schwarz(rows, n, blocks, inner, max_iters=300):
    """Multiplicative zero-overlap Schwarz, ConvergenceCheck fp floor."""
    nn = n * n
    x = np.zeros(nn)
    floor = 64.0 * np.finfo(float).eps * np.sqrt(nn)
    tol_eff = max(1e-13, floor)
    norms = []
    for _ in range(max_iters):
        x_prev = x.copy()
        for bi, block in enumerate(blocks):
            cols, b_rows, w, y, halo = block
            b_eff = y.copy()
            for (r_loc, gc, v) in halo:
                b_eff[r_loc] -= v * x[gc]
            x_loc = inner(bi, block, b_eff)
            x[cols] = x_loc
        rel = np.linalg.norm(x - x_prev) / (1.0 + np.linalg.norm(x))
        norms.append(rel)
        if rel < tol_eff:
            return x, len(norms), True
        if len(norms) >= 12:
            recent = min(norms[-6:])
            prior = min(norms[-12:-6])
            if recent >= prior * 0.95:
                return x, len(norms), False  # stalled
    return x, len(norms), False


def main():
    failures = 0

    # ---- 3. weighted_gram rewrite: upper + mirror vs full accumulation
    for seed in range(5):
        r = np.random.default_rng(seed)
        a = r.normal(size=(40, 17))
        d = r.uniform(0.5, 1.5, size=40)
        full = (a.T * d) @ a
        upper = np.zeros((17, 17))
        for i in range(40):
            row = a[i]
            for x_ in range(17):
                v = d[i] * row[x_]
                upper[x_, x_:] += v * row[x_:]
        sym = np.triu(upper) + np.triu(upper, 1).T
        err = np.abs(sym - full).max()
        assert err < 1e-12, f"gram rewrite mismatch {err}"
        assert np.array_equal(sym, sym.T), "mirrored gram not exactly symmetric"
    print("gram upper+mirror rewrite: OK (<=1e-12 vs full, exactly symmetric)")

    # ---- 1 & 2 & 4. CG local solves inside the Schwarz loop
    for n, m_obs in [(16, 120), (32, 400), (48, 800)]:
        rows, nn = build_problem2d(n, m_obs, seed=7 + n)
        blocks = local_blocks_2x2(rows, n)

        # Per-block operator state (dense oracle + matrix-free pieces).
        dense_a = [block_dense(b) for b in blocks]
        grams = [(a.T * b[2]) @ a for a, b in zip(dense_a, blocks)]
        chols = [np.linalg.cholesky(g) for g in grams]
        diag_inv = [1.0 / np.diag(g) for g in grams]

        cg_iter_max = [0]
        cg_iter_total = [0, 0]
        warm = {}

        def inner_exact(bi, block, b_eff):
            rhs = dense_a[bi].T @ (block[2] * b_eff)
            L = chols[bi]
            return np.linalg.solve(L.T, np.linalg.solve(L, rhs))

        def inner_cg(bi, block, b_eff):
            a = dense_a[bi]
            w = block[2]
            rhs = a.T @ (w * b_eff)
            nloc = a.shape[1]
            # Warm start from the previous solve of the same block, as
            # SparseCg does.
            x, it, conv, rel = pcg(lambda v: a.T @ (w * (a @ v)), rhs,
                                   diag_inv[bi], 1e-13, 10 * nloc + 200,
                                   x0=warm.get(bi))
            warm[bi] = x
            cg_iter_max[0] = max(cg_iter_max[0], it)
            cg_iter_total[0] += it
            cg_iter_total[1] += 1
            assert rel <= 1e-6, f"CG accept_tol breached: rel={rel}"
            return x

        xa, ia, ca = schwarz(rows, n, blocks, inner_exact)
        xb, ib, cb = schwarz(rows, n, blocks, inner_cg)
        gap = np.linalg.norm(xa - xb)
        cap = 10 * (n // 2) ** 2 + 200
        status = "OK" if gap <= 1e-8 else "FAIL"
        if gap > 1e-8:
            failures += 1
        mean_inner = cg_iter_total[0] / max(cg_iter_total[1], 1)
        print(f"n={n:3d} ({nn:5d} unknowns): exact iters={ia} cg iters={ib} "
              f"inner CG iters max={cg_iter_max[0]} mean={mean_inner:.1f} (cap {cap}) "
              f"fixed-point gap={gap:.2e} [{status}]")

        # Optimality certificate as in examples/sparse_scaling: sparse
        # normal residual of the CG analysis.
        res = np.zeros(nn)
        rhsv = np.zeros(nn)
        for (rcols, rvals, w, y) in rows:
            ax = sum(v * xb[c] for c, v in zip(rcols, rvals))
            for c, v in zip(rcols, rvals):
                res[c] += w * v * (y - ax)
                rhsv[c] += w * v * y
        rel_nr = np.linalg.norm(res) / np.linalg.norm(rhsv)
        print(f"        sparse normal residual of CG analysis: {rel_nr:.2e}")
        if rel_nr > 1e-6:
            failures += 1

    if failures:
        raise SystemExit(f"{failures} FAILURES")
    print("sparse_cg_sim: all checks passed")


if __name__ == "__main__":
    main()
