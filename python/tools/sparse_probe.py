#!/usr/bin/env python3
"""Stamp the repo-root `BENCH_sparse.json` with *measured* timings when no
Rust toolchain is available.

Timed port of the A7 cells in `rust/benches/ablations.rs`: block (0, 0)
of the uniform 2x2 box partition of an n x n FivePoint + gaussian-blob
problem (the `scaling_probe` problem family), dense weighted-Gram
Cholesky vs matrix-free Jacobi-PCG, one assemble plus 10 solves per
backend against perturbed right-hand sides (CG warm-starts, so identical
rhs would make solves 2..K near-free and inflate the speedup — same
guard as the Rust bench).

Every `t_*_s` field is a real `time.perf_counter()` measurement of this
process; `cargo xtask bench-refresh` (the CI bench job) overwrites the
document with Rust measurements. The schema matches the A7 emitter
field for field.

Run: python3 python/tools/sparse_probe.py  (writes BENCH_sparse.json at
the repo root)
"""

import json
import time
from pathlib import Path

import numpy as np

from scaling_probe import CgLocal, DenseLocal, build_problem, extract_blocks

SEED = 77
GRIDS = [32, 64, 96, 128]
SOLVES = 10


def run_cell(n):
    """One measured (grid) cell: assemble + SOLVES solves per backend on
    block (0, 0), timed separately for dense and cg."""
    rows = build_problem(n, (n * n) // 8, SEED)
    blk = extract_blocks(rows, n, 2, 2)[0]
    n_loc = blk["a"].shape[1]
    m_loc = blk["a"].shape[0]
    b_eff = blk["y"].copy()
    # Distinct rhs per timed solve, as in the Rust bench.
    bes = []
    for k in range(SOLVES):
        r = np.random.default_rng(1000 + k)
        bes.append(b_eff + 0.01 * r.standard_normal(len(b_eff)))

    t0 = time.perf_counter()
    dense = DenseLocal(blk)
    for be in bes:
        x_dense = dense.solve(be, None)
    t_dense = time.perf_counter() - t0

    t0 = time.perf_counter()
    cg = CgLocal(blk)
    warm = None
    for be in bes:
        warm = cg.solve(be, warm)
    x_cg = warm
    t_cg = time.perf_counter() - t0

    err = float(np.linalg.norm(x_dense - x_cg))
    return n_loc, m_loc, t_dense, t_cg, err


def main():
    rows_out = []
    for n in GRIDS:
        n_loc, m_loc, t_dense, t_cg, err = run_cell(n)
        speedup = t_dense / max(t_cg, 1e-9)
        print(f"{n:3d}² n_loc={n_loc:5d} m_loc={m_loc:5d} "
              f"dense={t_dense:7.3f}s cg={t_cg:7.3f}s "
              f"S={speedup:5.1f} err={err:.1e}")
        rows_out.append({
            "grid": n, "n_loc": n_loc, "m_loc": m_loc,
            "t_dense_s": round(t_dense, 6),
            "t_cg_s": round(t_cg, 6),
            "speedup": round(speedup, 4),
            "err_dense_vs_cg": err,
        })
    doc = {
        "bench": "sparse",
        "measured": True,
        "solves_per_backend": SOLVES,
        "note": ("seed baseline measured by python/tools/sparse_probe.py — "
                 "a timed single-process port of the A7 cells (dense "
                 "weighted-Gram Cholesky vs Jacobi-PCG on block (0,0) of "
                 "the 2x2 box partition). `cargo xtask bench-refresh` "
                 "replaces this document with Rust measurements."),
        "source": "python/tools/sparse_probe.py",
        "rows": rows_out,
    }
    out = Path(__file__).resolve().parents[2] / "BENCH_sparse.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
