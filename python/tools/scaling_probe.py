#!/usr/bin/env python3
"""Seed the repo-root `BENCH_scaling.json` with *measured* wall-clock
numbers when no Rust toolchain is available.

This is a timed port of the A9 strong-scaling cells in
`rust/benches/ablations.rs` (same problem family as
`python/tools/sparse_cg_sim.py`): FivePoint state rows + gaussian-blob
bilinear observation rows on an n x n grid, split into a px x py box
grid (p = px * py), zero-overlap multiplicative Schwarz over
checkerboard phases, with two local backends:

 * dense  — per-block weighted Gram + Cholesky factorization, cold
            (factor + solve) vs warm (cached factor, warm-started);
 * cg     — per-block Jacobi-preconditioned CG on the matrix-free
            normal operator (the `SparseCg` port), tol 1e-13.

Every `t_wall_*` field is a real `time.perf_counter()` measurement of
this process. The container is single-CPU, so blocks execute
sequentially and the dense-backend speedup at p > 1 is the
decomposition's algorithmic effect (p blocks of (n/p) unknowns cost
~n^3/p^2 to factor vs n^3 for one block), not thread parallelism;
`t_critical_s` is the simulated parallel critical path (sum over outer
sweeps of the max per-phase block time), as in the Rust coordinator.
`cargo xtask bench-refresh` (the CI bench job) overwrites this document
with multi-worker Rust measurements; the schema here matches the A9
emitter field for field.

Run: python3 python/tools/scaling_probe.py  (writes BENCH_scaling.json
at the repo root)
"""

import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np
import scipy.sparse as sp
import scipy.sparse.linalg  # noqa: F401  (registers the .linalg accessor)

SEED = 7
OBS_PER_AXIS = 8
GRIDS = [64, 128, 256]
DENSE_CAP = 64
WORKERS = [1, 2, 4, 8]


def grid_of(p):
    """Subdomain grid for p workers, as in examples/scaling_sweep.rs."""
    return {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2)}.get(p, (p, 1))


def build_problem(n, m_obs, seed):
    """FivePoint{main=1.0, off=0.12} state rows (weight 4) + bilinear
    gaussian-blob obs rows (weight 100) — the rust generators' weight
    structure (values are irrelevant to timing/conditioning)."""
    r = np.random.default_rng(seed)
    rows = []  # (cols, vals, w, y)

    def idx(ix, iy):
        return iy * n + ix

    for iy in range(n):
        for ix in range(n):
            cols, vals = [], []
            if iy > 0:
                cols.append(idx(ix, iy - 1)); vals.append(0.12)
            if ix > 0:
                cols.append(idx(ix - 1, iy)); vals.append(0.12)
            cols.append(idx(ix, iy)); vals.append(1.0)
            if ix + 1 < n:
                cols.append(idx(ix + 1, iy)); vals.append(0.12)
            if iy + 1 < n:
                cols.append(idx(ix, iy + 1)); vals.append(0.12)
            rows.append((cols, vals, 4.0, r.normal()))
    for _ in range(m_obs):
        x = min(max(r.normal(0.3, 0.08), 0.0), 1.0 - 1e-12)
        y = min(max(r.normal(0.35, 0.08), 0.0), 1.0 - 1e-12)
        fx, fy = x * (n - 1), y * (n - 1)
        jx, jy = int(fx), int(fy)
        tx, ty = fx - jx, fy - jy
        cols, vals = [], []
        for (dx, dy, wgt) in [(0, 0, (1 - tx) * (1 - ty)), (1, 0, tx * (1 - ty)),
                              (0, 1, (1 - tx) * ty), (1, 1, tx * ty)]:
            if wgt != 0.0 and jx + dx < n and jy + dy < n:
                cols.append(idx(jx + dx, jy + dy)); vals.append(wgt)
        if cols:
            rows.append((cols, vals, 100.0, r.normal()))
    return rows


def extract_blocks(rows, n, px, py):
    """Zero-overlap px x py box restriction: per block the in-set rows as
    a scipy CSR, the weights, data, halo couplings and checkerboard
    phase (bx + by) mod 2."""
    xb = [round(i * n / px) for i in range(px + 1)]
    yb = [round(i * n / py) for i in range(py + 1)]
    blocks = []
    owner = np.empty(n * n, dtype=np.int64)
    box_of = []
    for by in range(py):
        for bx in range(px):
            box_of.append((bx, by))
    for bi, (bx, by) in enumerate(box_of):
        for iy in range(yb[by], yb[by + 1]):
            owner[iy * n + xb[bx]: iy * n + xb[bx + 1]] = bi
    for bi, (bx, by) in enumerate(box_of):
        cols = np.flatnonzero(owner == bi)
        colset = {int(gc): c for c, gc in enumerate(cols)}
        data, indices, indptr = [], [], [0]
        b_w, b_y, halo = [], [], []
        for (rcols, rvals, w, y) in rows:
            loc = [(colset[c], v) for c, v in zip(rcols, rvals) if c in colset]
            if not loc:
                continue
            r_loc = len(b_w)
            for c, v in loc:
                indices.append(c); data.append(v)
            indptr.append(len(indices))
            b_w.append(w)
            b_y.append(y)
            for c, v in zip(rcols, rvals):
                if c not in colset and v != 0.0:
                    halo.append((r_loc, c, v))
        a = sp.csr_matrix((data, indices, indptr), shape=(len(b_w), len(cols)))
        halo_arr = (np.array([h[0] for h in halo], dtype=np.int64),
                    np.array([h[1] for h in halo], dtype=np.int64),
                    np.array([h[2] for h in halo]))
        blocks.append({
            "cols": cols, "a": a, "w": np.array(b_w), "y": np.array(b_y),
            "halo": halo_arr, "phase": (bx + by) % 2,
        })
    return blocks


def pcg(apply_op, rhs, diag_inv, tol, max_iters, x0=None):
    """Port of rust `linalg::sparse::pcg` (Jacobi, warm start, stagnation
    window scaled as `stall_window(n) = max(120, n / 2)`)."""
    n = len(rhs)
    rhs_norm = np.linalg.norm(rhs)
    if rhs_norm == 0.0:
        return np.zeros(n), 0
    stall = max(120, n // 2)
    if x0 is not None:
        x = x0.copy()
        r = rhs - apply_op(x0)
    else:
        x = np.zeros(n)
        r = rhs.copy()
    z = r * diag_inv
    p = z.copy()
    rz = r @ z
    best, since_best, iters = np.inf, 0, 0
    while True:
        rel = np.linalg.norm(r) / rhs_norm
        if rel <= tol or iters >= max_iters:
            break
        if rel < best * 0.999:
            best, since_best = rel, 0
        else:
            since_best += 1
            if since_best >= stall:
                break
        q = apply_op(p)
        pq = p @ q
        if pq <= 0.0:
            break
        alpha = rz / pq
        x += alpha * p
        r -= alpha * q
        z = r * diag_inv
        rz_new = r @ z
        p = z + (rz_new / rz) * p
        rz = rz_new
        iters += 1
    return x, iters


class DenseLocal:
    """Per-block weighted Gram + Cholesky, as the `native` backend."""

    def __init__(self, blk):
        a = blk["a"].toarray()
        g = (a.T * blk["w"]) @ a
        self.at_w = a.T * blk["w"]
        self.f = np.linalg.cholesky(g)

    def solve(self, b_eff, _warm):
        rhs = self.at_w @ b_eff
        return np.linalg.solve(self.f.T, np.linalg.solve(self.f, rhs))


class CgLocal:
    """Per-block matrix-free Jacobi-PCG, as the `cg` backend."""

    def __init__(self, blk):
        a = blk["a"]
        self.a, self.w = a, blk["w"]
        g_diag = (a.multiply(a)).T @ blk["w"]
        self.diag_inv = 1.0 / np.asarray(g_diag).ravel()
        self.nloc = a.shape[1]

    def solve(self, b_eff, warm):
        rhs = self.a.T @ (self.w * b_eff)
        x, _ = pcg(lambda v: self.a.T @ (self.w * (self.a @ v)), rhs,
                   self.diag_inv, 1e-13, 10 * self.nloc + 200, x0=warm)
        return x


def schwarz(blocks, locals_, nn, x0=None, max_iters=200):
    """Multiplicative Schwarz over checkerboard phases; returns the
    analysis, outer sweeps and the simulated critical path (sum over
    sweeps of the max per-phase block wall time)."""
    x = x0.copy() if x0 is not None else np.zeros(nn)
    warm = [None] * len(blocks)
    floor = 64.0 * np.finfo(float).eps * np.sqrt(nn)
    tol_eff = max(1e-13, floor)
    phases = sorted({b["phase"] for b in blocks})
    t_crit = 0.0
    for sweep in range(1, max_iters + 1):
        x_prev = x.copy()
        for ph in phases:
            t_max = 0.0
            for bi, blk in enumerate(blocks):
                if blk["phase"] != ph:
                    continue
                hr, hc, hv = blk["halo"]
                b_eff = blk["y"].copy()
                if len(hr):
                    np.subtract.at(b_eff, hr, hv * x[hc])
                t0 = time.perf_counter()
                x_loc = locals_[bi].solve(b_eff, warm[bi])
                t_max = max(t_max, time.perf_counter() - t0)
                warm[bi] = x_loc
                x[blk["cols"]] = x_loc
            t_crit += t_max
        rel = np.linalg.norm(x - x_prev) / (1.0 + np.linalg.norm(x))
        if rel < tol_eff:
            return x, sweep, t_crit
    return x, max_iters, t_crit


def run_cell(n, backend, p, problem_cache):
    """One measured (grid, backend, p) cell: cold (extract + factor +
    solve) and warm (cached factors, warm-started re-solve)."""
    if n not in problem_cache:
        problem_cache[n] = build_problem(n, OBS_PER_AXIS * n, SEED)
    rows = problem_cache[n]
    px, py = grid_of(p)
    nn = n * n

    t0 = time.perf_counter()
    blocks = extract_blocks(rows, n, px, py)
    mk = DenseLocal if backend == "dense" else CgLocal
    locals_ = [mk(b) for b in blocks]
    x, iters, t_crit = schwarz(blocks, locals_, nn)
    t_cold = time.perf_counter() - t0

    t0 = time.perf_counter()
    _, warm_iters, _ = schwarz(blocks, locals_, nn, x0=x)
    t_warm = time.perf_counter() - t0
    return t_cold, t_warm, t_crit, iters, warm_iters


def oversubscription_cell(problem_cache):
    """The A9 oversubscription cell: p = 4 x cores subdomains on the 64²
    grid, warm ticks solved by a real thread pool of width W — W = p
    (one thread per subdomain, the legacy scheduler) vs W = cores (the
    core-bounded pool). NumPy releases the GIL inside the dense solves,
    so the contention between oversubscribed threads is genuinely
    measured. Block write-backs land after each phase's futures resolve,
    in block order, so the analysis is identical under either packing
    (asserted bitwise)."""
    cores = os.cpu_count() or 1
    p = 4 * cores
    n = 64
    if n not in problem_cache:
        problem_cache[n] = build_problem(n, OBS_PER_AXIS * n, SEED)
    blocks = extract_blocks(problem_cache[n], n, 4, cores)
    locals_ = [DenseLocal(b) for b in blocks]
    x0, _, _ = schwarz(blocks, locals_, n * n)
    phases = sorted({b["phase"] for b in blocks})
    ticks = 3

    def warm_ticks(width):
        x = x0.copy()
        wall = 0.0
        with ThreadPoolExecutor(max_workers=width) as pool_:
            for _ in range(ticks):
                t0 = time.perf_counter()
                for ph in phases:
                    members = [bi for bi, b in enumerate(blocks) if b["phase"] == ph]
                    b_effs = []
                    for bi in members:
                        hr, hc, hv = blocks[bi]["halo"]
                        b_eff = blocks[bi]["y"].copy()
                        if len(hr):
                            np.subtract.at(b_eff, hr, hv * x[hc])
                        b_effs.append(b_eff)
                    futs = [pool_.submit(locals_[bi].solve, be, None)
                            for bi, be in zip(members, b_effs)]
                    for bi, fut in zip(members, futs):
                        x[blocks[bi]["cols"]] = fut.result()
                wall += time.perf_counter() - t0
        return wall / ticks, x

    t_tpb, x_tpb = warm_ticks(p)       # legacy: one thread per subdomain
    t_cb, x_cb = warm_ticks(cores)     # core-bounded pool
    bitwise_ok = bool(np.array_equal(x_tpb.view(np.int64), x_cb.view(np.int64)))
    assert bitwise_ok, "pool width changed the analysis bitwise"
    speedup = t_tpb / max(t_cb, 1e-12)
    print(f"oversubscription (64², p={p} = 4x{cores} cores, warm ticks): "
          f"W=p {t_tpb:.4f}s vs W=cores {t_cb:.4f}s ({speedup:.2f}x)")
    return {
        "grid": n, "cores": cores, "p": p,
        "t_warm_thread_per_block_s": round(t_tpb, 6),
        "t_warm_core_bounded_s": round(t_cb, 6),
        "speedup_core_bounded": round(speedup, 4),
        "bitwise_workers_ok": bitwise_ok,
    }


def main():
    problem_cache = {}
    rows_out = []
    for n in GRIDS:
        for backend in ["dense", "cg"]:
            if backend == "dense" and n > DENSE_CAP:
                print(f"note: skipping dense on {n}² (capped at {DENSE_CAP}²)")
                continue
            w1 = None
            for p in WORKERS:
                t_cold, t_warm, t_crit, iters, warm_iters = \
                    run_cell(n, backend, p, problem_cache)
                if w1 is None:
                    w1 = t_cold
                speedup = w1 / max(t_cold, 1e-12)
                # Iters-normalized warm cost: wall of the warm re-solve per
                # Schwarz sweep it actually ran (matches the A9 emitter).
                t_per_sweep = t_warm / max(warm_iters, 1)
                print(f"{n:3d}² {backend:5s} p={p}: iters={iters:3d} "
                      f"cold={t_cold:8.3f}s warm={t_warm:7.3f}s "
                      f"sweep={t_per_sweep:7.3f}s "
                      f"crit={t_crit:7.3f}s S={speedup:.2f}")
                rows_out.append({
                    "grid": n, "backend": backend, "p": p, "iters": iters,
                    "t_wall_cold_s": round(t_cold, 6),
                    "t_wall_warm_s": round(t_warm, 6),
                    "t_per_sweep_s": round(t_per_sweep, 6),
                    "t_critical_s": round(t_crit, 6),
                    "speedup_wall": round(speedup, 4),
                })
    doc = {
        "bench": "scaling",
        "measured": True,
        "kernel_threads": 1,
        "obs_per_grid_axis": OBS_PER_AXIS,
        "seed": SEED,
        "note": ("seed baseline measured by python/tools/scaling_probe.py — "
                 "a timed single-process port of the A9 cells (1-CPU "
                 "container: blocks run sequentially, so dense speedup is "
                 "the algorithmic p*(n/p)^3 decomposition effect and "
                 "t_critical_s carries the simulated parallel path). "
                 "`cargo xtask bench-refresh` replaces this document with "
                 "multi-worker Rust measurements."),
        "source": "python/tools/scaling_probe.py",
        "oversubscription": oversubscription_cell(problem_cache),
        "rows": rows_out,
    }
    out = Path(__file__).resolve().parents[2] / "BENCH_scaling.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
