#!/usr/bin/env python3
"""Seed the repo-root `BENCH_comms.json` with *measured* numbers when no
Rust toolchain is available.

This is a timed port of the A11 communication-mode cells in
`rust/benches/ablations.rs` (same problem family and block extraction as
`python/tools/scaling_probe.py`), with the leader's comm-byte ledger
ported exactly:

 * full       — every solve dispatch ships the dense iterate:
                8*n payload + 8*n_loc reply per block per sweep;
 * restricted — ships only the block's read set (the halo columns its
                couplings actually load): 8*|read_set| + 8*n_loc reply;
 * delta      — first dispatch per solve call ships the full read set,
                later dispatches ship only the bitwise-changed entries at
                12 bytes each (u32 index + f64 value); an empty delta on
                the pure-solve dense backend skips the dispatch entirely
                (0 bytes, counted in `solves_skipped`).

`comm_bytes_saved` is the dense baseline 8*(n + n_loc) per
dispatched-or-skipped block minus the bytes actually moved. The modes
are wire shapes, never arithmetic: a block is only skipped when its
read-set inputs are bitwise unchanged, so its solve would reproduce the
standing solution exactly — asserted here by the full-vs-delta bitwise
gate on the p=8 cell, as in the Rust bench. The probe runs the
zero-overlap extraction (the Rust A11 cell runs overlap 2; both sit in
the `overlap <= 2` regime the delta exchange targets), so `scenario.
overlap` is 0 until `cargo xtask bench-refresh` replaces this document.

Run: python3 python/tools/comms_probe.py  (writes BENCH_comms.json at
the repo root)
"""

import json
import time
from pathlib import Path

import numpy as np

from scaling_probe import (DenseLocal, OBS_PER_AXIS, SEED, build_problem,
                           extract_blocks)

GRID = 64
TICKS = 3
PS = [4, 8, 16]
MODES = ["full", "restricted", "delta"]


def grid_of(p):
    return {4: (2, 2), 8: (4, 2), 16: (4, 4)}[p]


def read_set_of(blk):
    """Sorted distinct halo columns — the wire format of a restricted
    send (order is the format; deltas index into it)."""
    _, hc, _ = blk["halo"]
    return np.unique(hc)


class CommLedger:
    """Per-solve-call byte ledger, as kept by the leader. `delta` mode
    re-ships the full read set on the first dispatch to a block (the
    change tracker is per solve call), then only bitwise-changed
    entries."""

    def __init__(self, mode, nn, blocks, read_sets):
        self.mode = mode
        self.nn = nn
        self.read_sets = read_sets
        self.n_loc = [len(b["cols"]) for b in blocks]
        self.snap = [None] * len(blocks)
        self.comm_bytes = 0
        self.comm_bytes_saved = 0
        self.solves_skipped = 0

    def dispatch(self, bi, x):
        """Account one solve dispatch for block `bi` against iterate `x`;
        returns False when the dispatch is skipped (empty delta on a
        pure-solve backend)."""
        dense = 8 * (self.nn + self.n_loc[bi])
        rs = self.read_sets[bi]
        vals = x[rs]
        if self.mode == "full":
            actual = 8 * self.nn + 8 * self.n_loc[bi]
            sent = True
        elif self.mode == "restricted" or self.snap[bi] is None:
            actual = 8 * len(rs) + 8 * self.n_loc[bi]
            sent = True
        else:
            changed = int(np.count_nonzero(
                vals.view(np.int64) != self.snap[bi].view(np.int64)))
            if changed == 0:
                actual = 0
                sent = False
                self.solves_skipped += 1
            else:
                actual = 12 * changed + 8 * self.n_loc[bi]
                sent = True
        if self.mode == "delta":
            self.snap[bi] = vals.copy()
        self.comm_bytes += actual
        self.comm_bytes_saved += max(dense - actual, 0)
        return sent


def schwarz_call(blocks, locals_, nn, ledger, x0=None, max_iters=200):
    """One solve call (port of the scaling probe's `schwarz`), with every
    per-sweep dispatch routed through the ledger; skipped blocks keep
    the standing solution, which is bitwise what the solve would have
    produced."""
    x = x0.copy() if x0 is not None else np.zeros(nn)
    floor = 64.0 * np.finfo(float).eps * np.sqrt(nn)
    tol_eff = max(1e-13, floor)
    phases = sorted({b["phase"] for b in blocks})
    for sweep in range(1, max_iters + 1):
        x_prev = x.copy()
        for ph in phases:
            for bi, blk in enumerate(blocks):
                if blk["phase"] != ph:
                    continue
                if not ledger.dispatch(bi, x):
                    continue
                hr, hc, hv = blk["halo"]
                b_eff = blk["y"].copy()
                if len(hr):
                    np.subtract.at(b_eff, hr, hv * x[hc])
                x[blk["cols"]] = locals_[bi].solve(b_eff, None)
        rel = np.linalg.norm(x - x_prev) / (1.0 + np.linalg.norm(x))
        if rel < tol_eff:
            return x, sweep
    return x, max_iters


def comm_cell(rows, mode, p):
    """Cold call then TICKS warm calls under `mode`; returns the mean
    warm wall and the last warm call's (x, iters, ledger) — the outcome
    the Rust A11 emitter reports."""
    px, py = grid_of(p)
    nn = GRID * GRID
    blocks = extract_blocks(rows, GRID, px, py)
    read_sets = [read_set_of(b) for b in blocks]
    locals_ = [DenseLocal(b) for b in blocks]
    cold_ledger = CommLedger(mode, nn, blocks, read_sets)
    x, _ = schwarz_call(blocks, locals_, nn, cold_ledger)
    t_warm = 0.0
    for _ in range(TICKS):
        ledger = CommLedger(mode, nn, blocks, read_sets)
        t0 = time.perf_counter()
        x, iters = schwarz_call(blocks, locals_, nn, ledger, x0=x)
        t_warm += time.perf_counter() - t0
    return t_warm / TICKS, x, iters, ledger


def main():
    rows = build_problem(GRID, OBS_PER_AXIS * GRID, SEED)

    # The bitwise gate the whole feature is contracted on (p = 8).
    _, x_full, it_full, _ = comm_cell(rows, "full", 8)
    _, x_delta, it_delta, _ = comm_cell(rows, "delta", 8)
    assert it_full == it_delta, "comm mode changed the iteration count"
    assert np.array_equal(x_full.view(np.int64), x_delta.view(np.int64)), \
        "comm mode changed the analysis bitwise"
    print("bitwise gate: full vs delta identical on 64² dense p=8")

    rows_out = []
    for p in PS:
        full_bps = None
        for mode in MODES:
            tick, _, iters, led = comm_cell(rows, mode, p)
            bps = led.comm_bytes / max(iters, 1)
            if full_bps is None:
                full_bps = bps
            reduction = full_bps / max(bps, 1e-9)
            print(f"p={p:2d} {mode:10s}: {bps:10.0f} B/sweep "
                  f"({reduction:5.1f}x vs full), skipped={led.solves_skipped}, "
                  f"warm tick {tick:.4f}s")
            rows_out.append({
                "p": p, "mode": mode,
                "comm_bytes": led.comm_bytes,
                "comm_bytes_saved": led.comm_bytes_saved,
                "bytes_per_sweep": round(bps, 1),
                "reduction_vs_full": round(reduction, 3),
                "solves_skipped": led.solves_skipped,
                "iters": iters,
                "t_warm_tick_s": round(tick, 6),
            })
    doc = {
        "bench": "comms",
        "measured": True,
        "scenario": {
            "dim": 2, "grid": GRID, "backend": "dense", "overlap": 0,
            "warm_ticks": TICKS, "seed": SEED,
        },
        "bitwise_comm_ok": True,
        "note": ("seed baseline measured by python/tools/comms_probe.py — a "
                 "timed single-process port of the A11 cells with the "
                 "leader's comm-byte ledger (zero-overlap extraction; the "
                 "Rust cell runs overlap 2). `cargo xtask bench-refresh` "
                 "replaces this document with Rust measurements."),
        "source": "python/tools/comms_probe.py",
        "rows": rows_out,
    }
    out = Path(__file__).resolve().parents[2] / "BENCH_comms.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
