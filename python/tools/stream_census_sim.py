"""Changelog/incremental-census replay for the streaming engine (`stream`).

No Rust toolchain is available in the authoring container, so the
streaming subsystem's core claim — the incremental census folded over an
observation changelog is **bitwise identical** to a full recount after
every tick, across native per-row drift streams, replayed per-cycle
generators, and threshold-policy rebalances — is cross-checked here with
an exact-arithmetic port. Keep in sync with:

  - rust/src/domain/generators.rs      (StreamDrift + generate_drift)
  - rust/src/domain2d/generators.rs    (StreamDrift2d + generate_drift2d)
  - rust/src/stream/changelog.rs       (ObsDelta / RecordStore / IncrementalCensus)
  - rust/src/stream/source.rs          (DriftSource row diff, ReplaySource multiset diff)
  - rust/tests/stream.rs               (the in-language property tests this mirrors)

Run:  python3 python/tools/stream_census_sim.py

Mirrors the Rust arithmetic exactly where it matters for the census:
  - SplitMix64 Rng / Acklam norm_quantile / nearest-point census
    (imported from cycle_census_sim, the established ports)
  - StreamDrift / StreamDrift2d per-row position formulas: jitter drawn
    once at construction, positions re-evaluated per phase, so a
    row-aligned diff yields sparse `moved` deltas
  - ReplaySource's multiset diff of consecutive per-cycle record sets
    (full remove/add churn — the parity path for the cycle driver)
  - IncrementalCensus: +-1 per delta entry under the incumbent
    partition, underflow-checked, rebased on partition change

Values/noise are irrelevant to the census and drawn *after* positions on
the Rust side, so the position stream alone replays the arithmetic.
"""
import math
import struct
from collections import Counter

from cycle_census_sim import (
    Rng, norm_quantile, clamp01, nearest, census_1d, census_2d,
    from_targets, balance_ratio, cycle_rng, drift_blob_1d, drift_blob_2d,
    rebalance_2d, GOLDEN,
)

TAU = 0.9


def rem_euclid(v, w):
    """Exact port of Rust f64::rem_euclid for w > 0."""
    r = math.fmod(v, w)
    if r < 0.0:
        r += w
    return r


def round_half_away(v):
    """Rust f64::round for v >= 0."""
    return int(math.floor(v + 0.5))


# ---------------- native per-row streams (StreamDrift ports) ----------------

class StreamDrift1d:
    """Port of domain::generators::StreamDrift (moving layouts only)."""

    def __init__(self, layout, m, seed):
        self.layout = layout
        self.m = m
        rng = Rng(seed)
        self.u = [rng.uniform() for _ in range(m)]

    def positions(self, t):
        t = min(max(t, 0.0), 1.0)
        m = self.m
        out = []
        for i in range(m):
            if self.layout == 'translating_blob':
                m_u = m // 2
                if i < m_u:
                    x = (i + self.u[i]) / m_u
                else:
                    j, m_b = i - m_u, m - m_u
                    q = norm_quantile((j + self.u[i]) / m_b)
                    x = clamp01(0.28 + 0.06 * t + 0.16 * q)
            elif self.layout == 'rotating_band':
                c = 0.1 + 0.8 * t
                u = (i + self.u[i]) / m
                x = min(rem_euclid(c - 0.15 + 0.3 * u, 1.0), 1.0 - 1e-12)
            elif self.layout == 'appearing_cluster':
                m2 = min(round_half_away(t * m), m)
                mu = 0.75 if i < m2 else 0.22
                x = clamp01(mu + 0.06 * norm_quantile((i + self.u[i]) / m))
            else:
                raise ValueError(self.layout)
            out.append(x)
        return out


class StreamDrift2d:
    """Port of domain2d::generators::StreamDrift2d (moving layouts only)."""

    def __init__(self, layout, m, seed):
        self.layout = layout
        self.m = m
        rng = Rng(seed)
        self.u = [rng.uniform() for _ in range(m)]
        self.u2 = [rng.uniform() for _ in range(m)]

    def positions(self, t):
        t = min(max(t, 0.0), 1.0)
        m = self.m
        out = []
        for i in range(m):
            if self.layout == 'translating_blob':
                m_u = m // 2
                if i < m_u:
                    x = (i + self.u[i]) / m_u
                    y = min(rem_euclid(i * GOLDEN + self.u2[i] / m_u, 1.0), 1.0 - 1e-12)
                else:
                    j, m_b = i - m_u, m - m_u
                    q = (j + self.u[i]) / m_b
                    r = 0.16 * math.sqrt(-2.0 * math.log(1.0 - q))
                    th = 2.0 * math.pi * rem_euclid(j * GOLDEN + (self.u2[i] - 0.5) / m_b, 1.0)
                    cx, cy = 0.30 + 0.06 * t, 0.35 + 0.05 * t
                    x = clamp01(cx + r * math.cos(th))
                    y = clamp01(cy + r * math.sin(th))
            elif self.layout == 'rotating_band':
                th = math.pi * 0.5 * t
                sin_t, cos_t = math.sin(th), math.cos(th)
                s = -0.45 + 0.9 * (i + self.u[i]) / m
                w = 0.08 * (self.u2[i] - 0.5)
                x = clamp01(0.5 + s * cos_t - w * sin_t)
                y = clamp01(0.5 + s * sin_t + w * cos_t)
            elif self.layout == 'appearing_cluster':
                m2 = min(round_half_away(t * m), m)
                cx, cy = (0.75, 0.75) if i < m2 else (0.25, 0.25)
                q = (i + self.u[i]) / m
                r = 0.07 * math.sqrt(-2.0 * math.log(1.0 - q))
                th = 2.0 * math.pi * rem_euclid(i * GOLDEN + (self.u2[i] - 0.5) / m, 1.0)
                x = clamp01(cx + r * math.cos(th))
                y = clamp01(cy + r * math.sin(th))
            else:
                raise ValueError(self.layout)
            out.append((x, y))
        return out


# ---------------- per-cycle generators (ReplaySource feed) ----------------

def gen_cycle_1d(layout, m, t, rng):
    """Positions of generate_drift(layout, m, t) — locations are drawn
    before values on the Rust side, so the first draws replay exactly."""
    if layout == 'translating_blob':
        return drift_blob_1d(m, t, rng, 0.28, 0.06, 0.16)
    if layout == 'rotating_band':
        c = 0.1 + 0.8 * t
        return [min(rem_euclid(c - 0.15 + 0.3 * ((i + rng.uniform()) / m), 1.0), 1.0 - 1e-12)
                for i in range(m)]
    if layout == 'appearing_cluster':
        m2 = min(round_half_away(t * m), m)
        xs = []
        for count, mu in [(m - m2, 0.22), (m2, 0.75)]:
            for i in range(count):
                u = (i + rng.uniform()) / count
                xs.append(clamp01(mu + 0.06 * norm_quantile(u)))
        return xs
    raise ValueError(layout)


def sunflower(pts, count, cx, cy, sigma, rng):
    for i in range(count):
        u = (i + rng.uniform()) / count
        r = sigma * math.sqrt(-2.0 * math.log(1.0 - u))
        th = 2.0 * math.pi * rem_euclid(i * GOLDEN + (rng.uniform() - 0.5) / count, 1.0)
        pts.append((clamp01(cx + r * math.cos(th)), clamp01(cy + r * math.sin(th))))


def gen_cycle_2d(layout, m, t, rng):
    """Positions of generate_drift2d(layout, m, t)."""
    if layout == 'translating_blob':
        return drift_blob_2d(m, t, rng, (0.30, 0.35), (0.06, 0.05), 0.16)
    if layout == 'rotating_band':
        th = math.pi * 0.5 * t
        sin_t, cos_t = math.sin(th), math.cos(th)
        pts = []
        for i in range(m):
            s = -0.45 + 0.9 * (i + rng.uniform()) / m
            w = 0.08 * (rng.uniform() - 0.5)
            pts.append((clamp01(0.5 + s * cos_t - w * sin_t),
                        clamp01(0.5 + s * sin_t + w * cos_t)))
        return pts
    if layout == 'appearing_cluster':
        m2 = min(round_half_away(t * m), m)
        pts = []
        sunflower(pts, m - m2, 0.25, 0.25, 0.07, rng)
        sunflower(pts, m2, 0.75, 0.75, 0.07, rng)
        return pts
    raise ValueError(layout)


# ---------------- changelog / store / census (stream::changelog port) ----------------

def key(rec):
    """Bit-pattern record key (the census-relevant projection of rec_key):
    distinguishes -0.0/0.0 the way the Rust f64_key ordering does."""
    if isinstance(rec, tuple):
        return struct.pack('<' + 'd' * len(rec), *rec)
    return struct.pack('<d', rec)


def row_diff(prev, cur, tick):
    """DriftSource: row-aligned diff of consecutive native snapshots."""
    if prev is None:
        return {'added': list(cur), 'removed': [], 'moved': []}
    assert len(prev) == len(cur)
    moved = [(a, b) for a, b in zip(prev, cur) if key(a) != key(b)]
    return {'added': [], 'removed': [], 'moved': moved}


def multiset_diff(prev, cur, tick):
    """ReplaySource: multiset diff of consecutive per-cycle record sets."""
    if prev is None:
        return {'added': list(cur), 'removed': [], 'moved': []}
    pc, cc = Counter(key(r) for r in prev), Counter(key(r) for r in cur)
    of = {}
    for r in prev:
        of.setdefault(key(r), r)
    for r in cur:
        of.setdefault(key(r), r)
    added, removed = [], []
    for k, c in cc.items():
        for _ in range(c - pc.get(k, 0)):
            added.append(of[k])
    for k, c in pc.items():
        for _ in range(c - cc.get(k, 0)):
            removed.append(of[k])
    return {'added': added, 'removed': removed, 'moved': []}


class RecordStore:
    """Multiset of standing records keyed by bit pattern."""

    def __init__(self):
        self.counts = Counter()
        self.of = {}

    def add(self, rec):
        k = key(rec)
        self.counts[k] += 1
        self.of[k] = rec

    def remove(self, rec):
        k = key(rec)
        assert self.counts.get(k, 0) > 0, 'store underflow: removed a record not present'
        self.counts[k] -= 1
        if self.counts[k] == 0:
            del self.counts[k]
            del self.of[k]

    def apply(self, delta):
        for r in delta['added']:
            self.add(r)
        for r in delta['removed']:
            self.remove(r)
        for old, new in delta['moved']:
            self.remove(old)
            self.add(new)

    def records(self):
        return [self.of[k] for k, c in self.counts.items() for _ in range(c)]


class IncrementalCensus:
    """O(|delta|) census fold — must equal a full recount bitwise."""

    def __init__(self, p):
        self.c = [0] * p

    def apply(self, delta, owner):
        for r in delta['added']:
            self.c[owner(r)] += 1
        for r in delta['removed']:
            i = owner(r)
            assert self.c[i] > 0, 'census underflow'
            self.c[i] -= 1
        for old, new in delta['moved']:
            i = owner(old)
            assert self.c[i] > 0, 'census underflow (moved)'
            self.c[i] -= 1
            self.c[owner(new)] += 1

    def rebase(self, counts):
        self.c = list(counts)


# ---------------- owners (census arithmetic projections) ----------------

def owner_1d(x, n, bounds):
    g = nearest(x, n)
    p = len(bounds) - 1
    for i in range(p):
        if bounds[i] <= g < bounds[i + 1]:
            return i
    return p - 1


def owner_2d(pt, n, xbounds, ybounds):
    x, y = pt
    px = len(xbounds) - 1
    py = len(ybounds[0]) - 1
    ix, iy = nearest(x, n), nearest(y, n)
    bx = px - 1
    for i in range(px):
        if xbounds[i] <= ix < xbounds[i + 1]:
            bx = i
            break
    yb = ybounds[bx]
    by = py - 1
    for j in range(py):
        if yb[j] <= iy < yb[j + 1]:
            by = j
            break
    return by * px + bx


# ---------------- engine tick loops ----------------

def split_targets(m, p):
    targets = [m // p] * p
    for i in range(m % p):
        targets[i] += 1
    return targets


def run_stream_1d(layout, mode, n, p, m, K, seed, policy):
    """The serve tick loop, census arithmetic only: ingest delta, fold the
    incremental census, assert it equals a full recount bitwise, apply the
    rebalance policy (rebase on partition change)."""
    bounds = [i * n // p for i in range(p + 1)]
    store = RecordStore()
    census = IncrementalCensus(p)
    stream = StreamDrift1d(layout, m, seed) if mode == 'native' else None
    prev = None
    churn = rebs = 0
    for k in range(K):
        t = 0.0 if K <= 1 else k / (K - 1)
        if mode == 'native':
            cur = stream.positions(t)
            delta = row_diff(prev, cur, k)
        else:
            cur = gen_cycle_1d(layout, m, t, cycle_rng(seed, k))
            delta = multiset_diff(prev, cur, k)
        churn += len(delta['added']) + len(delta['removed']) + len(delta['moved'])
        store.apply(delta)
        census.apply(delta, lambda x: owner_1d(x, n, bounds))
        xs = store.records()
        # Tentpole invariant: incremental fold == full recount, bitwise.
        full = census_1d(xs, n, bounds)
        assert census.c == full, \
            f'{layout}/{mode} seed={seed} tick={k}: incremental {census.c} != recount {full}'
        # Store rebuild invariant: standing multiset == the snapshot.
        assert store.counts == Counter(key(x) for x in cur), \
            f'{layout}/{mode} seed={seed} tick={k}: store diverged from snapshot'
        bal = balance_ratio(census.c)
        reb = {'never': False, 'every': True, 'threshold': bal < TAU}[policy]
        if reb:
            rebs += 1
            grid = sorted(nearest(x, n) for x in xs)
            bounds = from_targets(n, grid, split_targets(len(xs), p))
            census.rebase(census_1d(xs, n, bounds))
        prev = cur
    return churn, rebs


def run_stream_2d(layout, mode, n, px, py, m, K, seed, policy):
    xbounds = [i * n // px for i in range(px + 1)]
    ycol = [j * n // py for j in range(py + 1)]
    ybounds = [list(ycol) for _ in range(px)]
    p = px * py
    store = RecordStore()
    census = IncrementalCensus(p)
    stream = StreamDrift2d(layout, m, seed) if mode == 'native' else None
    prev = None
    churn = rebs = 0
    for k in range(K):
        t = 0.0 if K <= 1 else k / (K - 1)
        if mode == 'native':
            cur = stream.positions(t)
            delta = row_diff(prev, cur, k)
        else:
            cur = gen_cycle_2d(layout, m, t, cycle_rng(seed, k))
            delta = multiset_diff(prev, cur, k)
        churn += len(delta['added']) + len(delta['removed']) + len(delta['moved'])
        store.apply(delta)
        census.apply(delta, lambda q: owner_2d(q, n, xbounds, ybounds))
        pts = store.records()
        full = census_2d(pts, n, xbounds, ybounds)
        assert census.c == full, \
            f'2d {layout}/{mode} seed={seed} tick={k}: incremental {census.c} != recount {full}'
        assert store.counts == Counter(key(q) for q in cur), \
            f'2d {layout}/{mode} seed={seed} tick={k}: store diverged from snapshot'
        bal = balance_ratio(census.c)
        reb = {'never': False, 'every': True, 'threshold': bal < TAU}[policy]
        if reb:
            rebs += 1
            xbounds, ybounds = rebalance_2d(pts, n, px, py, split_targets(len(pts), p))
            census.rebase(census_2d(pts, n, xbounds, ybounds))
        prev = cur
    return churn, rebs


LAYOUTS = ['translating_blob', 'rotating_band', 'appearing_cluster']


def main():
    ticks_checked = 0

    # 1-D: the BENCH_stream / stream_serve scenario shape, every moving
    # layout, both delta paths, all three policies.
    n, p, m, K = 512, 4, 800, 8
    for layout in LAYOUTS:
        for mode in ['native', 'replay']:
            for seed in [42, 7, 123]:
                for policy in ['threshold', 'every', 'never']:
                    churn, rebs = run_stream_1d(layout, mode, n, p, m, K, seed, policy)
                    ticks_checked += K
                    if policy == 'threshold':
                        print(f'1d {layout:17s} {mode:6s} seed={seed:<3d} '
                              f'|delta|/tick={churn / K:6.1f}  rebalances={rebs}')
            # Native streams with t-independent rows must be sparse: warm
            # churn strictly below a full re-materialization (the
            # O(|delta|) point of the path). rotating_band moves every row
            # each tick, so it is exempt.
            if mode == 'native' and layout != 'rotating_band':
                churn, _ = run_stream_1d(layout, mode, n, p, m, K, 42, 'threshold')
                warm = (churn - m) / (K - 1)
                assert warm < m, f'{layout}: native warm churn {warm} not sparse'

    # 2-D boxes: same invariants through the x-sweep/y-sweep realization.
    n2, px, py, m2, K2 = 96, 2, 2, 400, 6
    for layout in LAYOUTS:
        for mode in ['native', 'replay']:
            for seed in [42, 7]:
                for policy in ['threshold', 'every']:
                    churn, rebs = run_stream_2d(layout, mode, n2, px, py, m2, K2, seed, policy)
                    ticks_checked += K2
                    if policy == 'threshold':
                        print(f'2d {layout:17s} {mode:6s} seed={seed:<3d} '
                              f'|delta|/tick={churn / K2:6.1f}  rebalances={rebs}')

    print(f'\nOK: incremental census == full recount (bitwise) on every one of '
          f'{ticks_checked} ticks')


if __name__ == '__main__':
    main()
