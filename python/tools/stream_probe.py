#!/usr/bin/env python3
"""Stamp the repo-root `BENCH_stream.json` with *measured* timings when no
Rust toolchain is available.

Timed port of the A8 cells in `rust/benches/ablations.rs`: the K=16
translating-blob stream on a 1-D n=512 interval split into p=8 uniform
blocks (Tridiag{main=1.0, off=0.15} state rows, weight 4, plus
nearest-point observation rows, weight 100). The uniform half of the
observation set is emitted once and held; the blob half drifts tick to
tick (`DriftSource` delta semantics), so a block is dirty exactly when a
blob observation entered or left it:

 * incremental — re-extract + refactor dirty blocks only, warm-started
   multiplicative Schwarz from the previous tick's analysis;
 * cold       — forced re-extraction + refactorization of every block
   each tick (same warm-started outer solve).

Every tick-cost field is a real `time.perf_counter()` measurement of
this process; `cargo xtask bench-refresh` (the CI bench job) overwrites
the document with Rust measurements. The schema matches the A8 emitter
field for field.

Run: python3 python/tools/stream_probe.py  (writes BENCH_stream.json at
the repo root)
"""

import bisect
import json
import time
from pathlib import Path

import numpy as np
import scipy.sparse as sp

from cycle_census_sim import Rng, cycle_rng, drift_blob_1d, nearest
from scaling_probe import DenseLocal, schwarz

N = 512
P = 8
M = 800
TICKS = 16
SEED = 42
# DriftLayout::TranslatingBlob constants (see cycle_census_sim).
MU0, PATH, SIGMA = 0.28, 0.06, 0.16


def state_rows(n):
    """Tridiag{main=1.0, off=0.15} state rows, weight 4, fixed background."""
    bg = np.random.default_rng(123).standard_normal(n)
    rows = []
    for j in range(n):
        cols, vals = [], []
        if j > 0:
            cols.append(j - 1); vals.append(0.15)
        cols.append(j); vals.append(1.0)
        if j + 1 < n:
            cols.append(j + 1); vals.append(0.15)
        rows.append((cols, vals, 4.0, bg[j]))
    return rows


def obs_row(x, n, y):
    """Nearest-point observation of grid point `x`, weight 100."""
    return ([nearest(x, n)], [1.0], 100.0, y)


def extract_block(rows, bounds, bi):
    """One zero-overlap interval block: in-set rows as scipy CSR plus the
    halo couplings, shaped like `scaling_probe.extract_blocks` output.
    The block's own index is its Schwarz phase (multiplicative order)."""
    lo, hi = bounds[bi], bounds[bi + 1]
    cols = np.arange(lo, hi, dtype=np.int64)
    data, indices, indptr = [], [], [0]
    b_w, b_y, halo = [], [], []
    for (rcols, rvals, w, y) in rows:
        loc = [(c - lo, v) for c, v in zip(rcols, rvals) if lo <= c < hi]
        if not loc:
            continue
        r_loc = len(b_w)
        for c, v in loc:
            indices.append(c); data.append(v)
        indptr.append(len(indices))
        b_w.append(w)
        b_y.append(y)
        for c, v in zip(rcols, rvals):
            if not lo <= c < hi and v != 0.0:
                halo.append((r_loc, c, v))
    a = sp.csr_matrix((data, indices, indptr), shape=(len(b_w), hi - lo))
    halo_arr = (np.array([h[0] for h in halo], dtype=np.int64),
                np.array([h[1] for h in halo], dtype=np.int64),
                np.array([h[2] for h in halo]))
    return {"cols": cols, "a": a, "w": np.array(b_w), "y": np.array(b_y),
            "halo": halo_arr, "phase": bi}


def owner_of(g, bounds):
    return min(bisect.bisect_right(bounds, g) - 1, len(bounds) - 2)


def blob_ticks():
    """Per-tick blob observation rows (positions + values), uniform half
    held fixed: the `DriftSource` delta structure."""
    base = Rng(SEED)
    m_u = M // 2
    uniform = [obs_row((i + base.uniform()) / m_u, N, base.uniform() - 0.5)
               for i in range(m_u)]
    ticks = []
    for k in range(TICKS):
        t = 0.0 if TICKS <= 1 else k / (TICKS - 1)
        rng = cycle_rng(SEED, k)
        xs = drift_blob_1d(M, t, rng, MU0, PATH, SIGMA)[m_u:]
        ticks.append([obs_row(x, N, rng.uniform() - 0.5) for x in xs])
    return uniform, ticks


def run_mode(force_cold):
    """One full stream run; returns (x, tick wall times, dirty counts)."""
    bounds = [i * N // P for i in range(P + 1)]
    srows = state_rows(N)
    uniform, ticks = blob_ticks()
    blocks = [None] * P
    locals_ = [None] * P
    x = None
    walls, dirty_counts = [], []
    prev_touch = set()
    for k in range(TICKS):
        rows = srows + uniform + ticks[k]
        touch = {owner_of(r[0][0], bounds) for r in ticks[k]}
        dirty = set(range(P)) if (k == 0 or force_cold) else touch | prev_touch
        prev_touch = touch
        t0 = time.perf_counter()
        for bi in sorted(dirty):
            blocks[bi] = extract_block(rows, bounds, bi)
            locals_[bi] = DenseLocal(blocks[bi])
        x, _, _ = schwarz(blocks, locals_, N, x0=x)
        walls.append(time.perf_counter() - t0)
        dirty_counts.append(len(dirty))
    return x, walls, dirty_counts


def main():
    t0 = time.perf_counter()
    x_inc, w_inc, d_inc = run_mode(False)
    x_cold, w_cold, d_cold = run_mode(True)
    # Warm-tick statistics skip tick 0 (the unavoidable cold start), as in
    # the Rust A8 emitter.
    warm_mean = float(np.mean(w_inc[1:]))
    cold_mean = float(np.mean(w_cold[1:]))
    dirty_fraction = float(np.mean([d / P for d in d_inc[1:]]))
    cache_hit = float(np.mean([(P - d) / P for d in d_inc[1:]]))
    err = float(np.linalg.norm(x_inc - x_cold))
    print(f"incremental: factors={sum(d_inc)} warm_tick={warm_mean:.4f}s "
          f"cache_hit={cache_hit:.3f}")
    print(f"cold:        factors={sum(d_cold)} warm_tick={cold_mean:.4f}s")
    print(f"speedup={cold_mean / max(warm_mean, 1e-12):.2f} err={err:.1e} "
          f"({time.perf_counter() - t0:.1f}s total)")
    doc = {
        "bench": "stream",
        "measured": True,
        "scenario": {
            "dim": 1, "n": N, "m": M, "p": P, "ticks": TICKS, "seed": SEED,
            "drift": "translating_blob", "source": "drift",
        },
        "warm_tick_mean_s": round(warm_mean, 6),
        "cold_tick_mean_s": round(cold_mean, 6),
        "speedup": round(cold_mean / max(warm_mean, 1e-12), 4),
        "dirty_block_fraction": round(dirty_fraction, 6),
        "cache_hit_rate": round(cache_hit, 6),
        "factorizations_incremental": sum(d_inc),
        "factorizations_cold": sum(d_cold),
        "err_incremental_vs_cold": err,
        "note": ("seed baseline measured by python/tools/stream_probe.py — "
                 "a timed single-process port of the A8 scenario "
                 "(dirty-block incremental vs forced cold re-extraction on "
                 "the K=16 drifting blob). `cargo xtask bench-refresh` "
                 "replaces this document with Rust measurements."),
        "source": "python/tools/stream_probe.py",
    }
    out = Path(__file__).resolve().parents[2] / "BENCH_stream.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
