"""Weighted gram kernels: G = A^T D A and c = A^T D r (D = diag(d) >= 0).

These are the normal-equations assembly of the CLS solve — the dominant
cost of every local Schwarz subproblem (O(M n_loc^2) flops, the paper's
per-subdomain compute). The kernel is the canonical TPU matmul shape:

  grid = (n/bn, n/bn, M/bm); the (bn x bn) output tile for (i, j) stays
  resident in VMEM while the k axis streams (bm x bn) panels of A from HBM.
  The contraction `a_i^T @ (d * a_j)` is MXU-shaped (bn x bm @ bm x bn).

Row padding is exact: padded rows carry d = 0 and contribute nothing.
Column padding is handled downstream by the diagonal regularization vector
(see model.assemble_fn).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import choose_blocks


def _gram_kernel(a_i_ref, a_j_ref, d_ref, g_ref):
    """One (i, j, k) grid step: accumulate a_i^T D a_j into the (i, j) tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    a_i = a_i_ref[...]  # (bm, bn)
    a_j = a_j_ref[...]  # (bm, bn)
    d = d_ref[...]  # (bm,)
    # Scale the streaming panel once; the contraction then feeds the MXU.
    g_ref[...] += jnp.dot(a_i.T, d[:, None] * a_j, precision="highest")


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def weighted_gram(a, d, *, block_m: int | None = None, block_n: int | None = None):
    """G = A^T diag(d) A for A: (M, N), d: (M,). Returns (N, N)."""
    m, n = a.shape
    if block_m is None or block_n is None:
        bm, bn = choose_blocks(m, n, a.dtype.itemsize)
        block_m = block_m or bm
        block_n = block_n or bn
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (n // block_n, n // block_n, m // block_m)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((block_n, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a, a, d)


def _at_db_kernel(a_ref, d_ref, r_ref, c_ref):
    """One (j, k) grid step: accumulate a^T (d * r) into the j-th block of c."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    a = a_ref[...]  # (bm, bn)
    dr = d_ref[...] * r_ref[...]  # (bm,)
    c_ref[...] += jnp.dot(a.T, dr, precision="highest")


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def at_db(a, d, r, *, block_m: int | None = None, block_n: int | None = None):
    """c = A^T diag(d) r for A: (M, N), d, r: (M,). Returns (N,)."""
    m, n = a.shape
    if block_m is None or block_n is None:
        bm, bn = choose_blocks(m, n, a.dtype.itemsize)
        block_m = block_m or bm
        block_n = block_n or bn
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (n // block_n, m // block_m)
    return pl.pallas_call(
        _at_db_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda j, k: (k, j)),
            pl.BlockSpec((block_m,), lambda j, k: (k,)),
            pl.BlockSpec((block_m,), lambda j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda j, k: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, d, r)
