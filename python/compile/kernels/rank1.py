"""Fused Kalman rank-1 covariance update: P <- P - k w^T.

This is the Corrector-phase hot spot of sequential KF observation
processing (eq. 7-8 of the paper with one observation row at a time):
given the gain k = P h / s and w = P h, the covariance update
(I - k h^T) P simplifies to P - k w^T because P is symmetric. Fusing the
outer product into a tiled in-place subtraction avoids materializing K H
(n x n) and halves HBM traffic versus the naive two-matmul form.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import choose_blocks


def _outer_update_kernel(p_ref, k_ref, w_ref, o_ref):
    o_ref[...] = p_ref[...] - k_ref[...][:, None] * w_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block",))
def outer_update(p, k, w, *, block: int | None = None):
    """P - outer(k, w) for P: (n, n), k, w: (n,). Returns (n, n)."""
    n = p.shape[0]
    assert p.shape == (n, n) and k.shape == (n,) and w.shape == (n,)
    if block is None:
        _, block = choose_blocks(n, n, p.dtype.itemsize)
    assert n % block == 0, (n, block)
    grid = (n // block, n // block)
    return pl.pallas_call(
        _outer_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((block,), lambda i, j: (i,)),
            pl.BlockSpec((block,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), p.dtype),
        interpret=True,
    )(p, k, w)
