"""Layer-1 Pallas kernels: the numeric hot spots of DD-KF on CLS.

All kernels are written against the TPU mental model (VMEM-resident output
tiles, HBM->VMEM streaming expressed through BlockSpec, MXU-shaped
contractions) but are lowered with ``interpret=True`` so the resulting HLO
runs on the CPU PJRT client — real-TPU lowering emits Mosaic custom-calls
the CPU plugin cannot execute. See DESIGN.md §Hardware-Adaptation.
"""

from .gram import at_db, weighted_gram  # noqa: F401
from .matvec import matvec  # noqa: F401
from .rank1 import outer_update  # noqa: F401
from .residual import weighted_residual_sq  # noqa: F401
from .tiling import choose_blocks, vmem_bytes  # noqa: F401
