"""Blocked matvec kernel: y = A x.

Used by the KF rank-1 analysis step (w = P h, the O(n^2) half of each
observation update) and by diagnostics. Grid streams (bm x bn) panels of A;
the (bm,) output block accumulates across the j axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import choose_blocks


def _matvec_kernel(a_ref, x_ref, y_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(a_ref[...], x_ref[...], precision="highest")


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matvec(a, x, *, block_m: int | None = None, block_n: int | None = None):
    """y = A @ x for A: (M, N), x: (N,). Returns (M,)."""
    m, n = a.shape
    if block_m is None or block_n is None:
        bm, bn = choose_blocks(m, n, a.dtype.itemsize)
        block_m = block_m or bm
        block_n = block_n or bn
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)
