"""Block-size selection and VMEM accounting for the Pallas kernels.

On a real TPU the constraint is VMEM (~16 MiB/core on v4): the gram kernel
keeps one (bn x bn) output tile resident plus two (bm x bn) input panels and
a (bm,) weight slice, all at the working dtype. We pick the largest blocks
that keep the projected footprint under a conservative budget and divide the
bucket dims exactly (buckets are multiples of 128/256 by construction, see
shapes.py). Interpret-mode wallclock is *not* a TPU proxy; these choices are
validated structurally (footprint + MXU-shape) in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

# Conservative VMEM budget (bytes) for one kernel invocation's working set.
VMEM_BUDGET = 12 * 1024 * 1024

# MXU-friendly tile quanta: the systolic array is 128x128; sublane quantum
# for f32 is 8. We only ever pick multiples of these.
LANE = 128
SUBLANE = 8


def vmem_bytes(bm: int, bn: int, itemsize: int = 8) -> int:
    """Projected VMEM working set of the gram kernel for (bm, bn) blocks.

    Two input panels (bm x bn), one output tile (bn x bn), one weight slice
    (bm,), plus a scaled-panel temporary (bm x bn).
    """
    return itemsize * (3 * bm * bn + bn * bn + bm)


def _largest_divisor_block(dim: int, cap: int) -> int:
    """Largest b <= cap with b | dim, preferring multiples of LANE."""
    b = min(dim, cap)
    while b > 1 and dim % b != 0:
        b //= 2
    return max(b, 1)


def choose_blocks(m: int, n: int, itemsize: int = 8) -> tuple[int, int]:
    """Pick (bm, bn) for an (m, n) operand under the VMEM budget.

    Defaults target bm=256, bn=128 (the §Perf sweep winner); shrink bm first
    (streaming dim) if the budget is exceeded, then bn.
    """
    bn = _largest_divisor_block(n, 128)
    bm = _largest_divisor_block(m, 256)
    while vmem_bytes(bm, bn, itemsize) > VMEM_BUDGET and bm > SUBLANE:
        bm //= 2
    while vmem_bytes(bm, bn, itemsize) > VMEM_BUDGET and bn > SUBLANE:
        bn //= 2
    return bm, bn
