"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/) sweeps shapes/dtypes with hypothesis and asserts
allclose between each kernel and its oracle here. Nothing in this module is
ever lowered into an artifact.
"""

from __future__ import annotations

import jax.numpy as jnp


def weighted_gram(a, d):
    """G = A^T diag(d) A."""
    return a.T @ (d[:, None] * a)


def at_db(a, d, r):
    """c = A^T diag(d) r."""
    return a.T @ (d * r)


def matvec(a, x):
    """y = A x."""
    return a @ x


def outer_update(p, k, w):
    """P - outer(k, w)."""
    return p - jnp.outer(k, w)


def weighted_residual_sq(a, x, b, d):
    """sum(d * (A x - b)^2)."""
    r = a @ x - b
    return jnp.sum(d * r * r)


def kf_rank1_step(x, p, h, rvar, y):
    """One sequential-KF observation update (eqs. 7-8, single row h).

    Returns (x', P'). Padded rows are encoded as h = 0, rvar = 1, y = 0 and
    are exact no-ops.
    """
    w = p @ h
    s = h @ w + rvar
    k = w / s
    x = x + k * (y - h @ x)
    p = p - jnp.outer(k, w)
    return x, p


def cls_solve(a, d, b, diag_reg):
    """x = (A^T D A + diag(diag_reg))^{-1} (A^T D b) — dense reference."""
    g = weighted_gram(a, d) + jnp.diag(diag_reg)
    return jnp.linalg.solve(g, at_db(a, d, b))
