"""Weighted residual kernel: J(x) = || A x - b ||^2_D = sum_i d_i (A x - b)_i^2.

The CLS objective (eq. 17) restricted to a subdomain; used by the Schwarz
convergence check and the benchmark harness. Single-pass: each row panel
computes its local residual and accumulates the scalar into a (1, 1) output
tile that stays resident across the whole grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import choose_blocks


def _residual_kernel(a_ref, x_ref, b_ref, d_ref, o_ref, r_ref):
    """(i, j) grid step. The second output r is a per-row-panel residual
    accumulator (r = A x - b, built up across the j axis); on the last j
    step its weighted square is folded into the grid-resident scalar o."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when((i == 0) & (j == 0))
    def _init_o():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j == 0)
    def _init_r():
        r_ref[...] = -b_ref[...]

    r_ref[...] += jnp.dot(a_ref[...], x_ref[...], precision="highest")

    @pl.when(j == nj - 1)
    def _fold():
        r = r_ref[...]
        o_ref[...] += jnp.sum(d_ref[...] * r * r)[None]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def weighted_residual_sq(
    a, x, b, d, *, block_m: int | None = None, block_n: int | None = None
):
    """sum(d * (A x - b)^2) for A: (M, N). Returns a scalar (shape (1,))."""
    m, n = a.shape
    if block_m is None or block_n is None:
        bm, bn = choose_blocks(m, n, a.dtype.itemsize)
        block_m = block_m or bm
        block_n = block_n or bn
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)
    out, _ = pl.pallas_call(
        _residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), a.dtype),
            jax.ShapeDtypeStruct((m,), a.dtype),
        ],
        interpret=True,
    )(a, x, b, d)
    return out
