"""AOT pipeline: lower every L2 function at its shape buckets to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only NAME_SUBSTR] [--force]

Python runs ONLY here (and in pytest). The rust binary is self-contained
once artifacts/ is built; `make artifacts` is a no-op when inputs are
unchanged (mtime-based, plus a content fingerprint in the manifest).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model, shapes  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def source_fingerprint() -> str:
    """Hash of the compile-path sources; stored in the manifest so stale
    artifacts are detectable even when mtimes lie (e.g. git checkout)."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


# Kinds whose CPU lowering emits typed-FFI LAPACK custom-calls
# (lapack_dpotrf_ffi / lapack_dtrsm_ffi) that xla_extension 0.5.1 cannot
# execute. Lowering these for the TPU platform emits the *builtin* HLO
# Cholesky / TriangularSolve ops instead, which the CPU PJRT client expands
# natively — numerics verified against scipy in python/tests and against
# the rust-native path in cargo tests. (The Schwarz hot-path artifacts
# assemble/solve avoid factorization entirely — see model.assemble_fn.)
_TPU_LOWERED_KINDS = {"cls_full"}


def lower_spec(spec) -> str:
    fn = model.FUNCTIONS[spec.kind]
    args = model.make_example_args(spec)
    if spec.kind in _TPU_LOWERED_KINDS:
        lowered = jax.jit(fn).trace(*args).lower(lowering_platforms=("tpu",))
    else:
        lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    assert "custom-call" not in text, f"{spec.name}: unexpected custom-call"
    return text


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on names")
    ap.add_argument("--force", action="store_true", help="re-lower everything")
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = shapes.manifest_dict()
    manifest["fingerprint"] = source_fingerprint()
    manifest_path = out_dir / "manifest.json"

    old_fp = None
    if manifest_path.exists():
        try:
            old_fp = json.loads(manifest_path.read_text()).get("fingerprint")
        except (json.JSONDecodeError, OSError):
            old_fp = None
    force = args.force or old_fp != manifest["fingerprint"]

    specs = shapes.all_specs()
    if args.only:
        specs = [s for s in specs if args.only in s.name]

    t_total = time.time()
    n_done = n_skip = 0
    for spec in specs:
        path = out_dir / spec.filename
        if path.exists() and not force:
            n_skip += 1
            continue
        t0 = time.time()
        text = lower_spec(spec)
        path.write_text(text)
        n_done += 1
        print(
            f"  lowered {spec.name:28s} {len(text) / 1024:9.1f} KiB"
            f"  {time.time() - t0:6.2f}s",
            flush=True,
        )

    if not args.only:
        manifest_path.write_text(json.dumps(manifest, indent=1))
    print(
        f"artifacts: {n_done} lowered, {n_skip} up-to-date"
        f" ({time.time() - t_total:.1f}s) -> {out_dir}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
