"""Shape-bucket registry shared by the AOT pipeline, tests and the manifest.

Every HLO artifact is lowered at a fixed shape. The rust runtime picks, per
subdomain, the smallest bucket that fits the actual local problem
(rows are padded with zero weights, columns with unit diagonal
regularization — both padding schemes are exact, see kernels/gram.py).

Buckets are sized for the paper's experiments (n = 2048 unknowns,
m <= 2000 observations, p in {1,2,4,8,16,32}) plus small test/e2e sizes.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

# (M_rows, n_loc) buckets for the local Schwarz solve artifacts.
#
# A local subproblem has M_loc = (state rows with support in the subdomain)
# + (observations located in the subdomain) rows and n_loc columns. DyDD
# migration shifts spatial boundaries, so n_loc drifts away from n/p on
# clustered workloads — the bucket grid is therefore finer than powers of
# two (quarter steps) to bound column-padding waste, and every value is
# divisible by an MXU-friendly block (see kernels/tiling.py).
#
# With the paper's parameters the post-balance loads are l_i ~= m/p, e.g.:
#   p=2,  n=2048, m=2000 -> n_loc=1024, M_loc ~= 1024+2+1000 -> (2560, 1024)
#   p=32, n=2048, m=1032 -> n_loc=64,   M_loc ~= 64+2+33     -> (128, 64)
NLOCS: List[int] = [
    32, 48, 64, 96, 128, 160, 192, 224, 256, 320, 384, 448, 512,
    640, 768, 896, 1024, 1280, 1536, 1792, 2048,
]  # fmt: skip

MROWS: List[int] = [
    64, 96, 128, 192, 256, 384, 512, 768, 1024, 1536, 2048, 2560, 3072, 4608,
]  # fmt: skip


def _useful(m: int, n: int) -> bool:
    """Keep (m, n) pairs a real subproblem could need: at least the state
    rows (n+2) must fit, and anything beyond n + m_max(=2048) + slack is
    never requested."""
    return m >= n + 34 and m <= n + 3072


ASSEMBLE_PAIRS: List[Tuple[int, int]] = [
    (m, n) for n in NLOCS for m in MROWS if _useful(m, n)
]

# (n, chunk) for the sequential KF baseline artifact: a lax.scan of `chunk`
# rank-1 observation updates over state dim n (used by the T^1 baseline and
# the e2e driver's analysis step).
KF_CHUNK_PAIRS: List[Tuple[int, int]] = [
    (64, 16),
    (128, 32),
    (256, 32),
    (2048, 64),
]

# n for the dense KF predict artifact: P' = M P M^T + Q, x' = M x.
KF_PREDICT_SIZES: List[int] = [64, 128, 256]

# (M_rows, n) for the full-problem CLS reference solve (used to compute
# error_DD-DA against the global solution).
CLS_FULL_PAIRS: List[Tuple[int, int]] = [
    (256, 64),
    (256, 128),
    (512, 256),
    (2560, 1024),
    (4608, 2048),
]


@dataclasses.dataclass(frozen=True)
class ArtifactSpec:
    """One AOT artifact: a jax function lowered at a fixed shape."""

    name: str  # e.g. "assemble_m256_n64"
    kind: str  # assemble | solve | matvec | kf_chunk | kf_predict | cls_full
    dims: dict  # kind-specific dims, mirrored into the manifest

    @property
    def filename(self) -> str:
        return f"{self.name}.hlo.txt"


def all_specs() -> List[ArtifactSpec]:
    specs: List[ArtifactSpec] = []
    for m, n in ASSEMBLE_PAIRS:
        specs.append(
            ArtifactSpec(f"assemble_m{m}_n{n}", "assemble", {"m": m, "nloc": n})
        )
        specs.append(ArtifactSpec(f"solve_m{m}_n{n}", "solve", {"m": m, "nloc": n}))
    for n, c in KF_CHUNK_PAIRS:
        specs.append(
            ArtifactSpec(f"kf_chunk_n{n}_c{c}", "kf_chunk", {"n": n, "chunk": c})
        )
    for n in KF_PREDICT_SIZES:
        specs.append(ArtifactSpec(f"kf_predict_n{n}", "kf_predict", {"n": n}))
    for m, n in CLS_FULL_PAIRS:
        specs.append(ArtifactSpec(f"cls_full_m{m}_n{n}", "cls_full", {"m": m, "n": n}))
    return specs


def manifest_dict() -> dict:
    return {
        "version": 1,
        "dtype": "f64",
        "artifacts": [
            {"name": s.name, "kind": s.kind, "file": s.filename, **s.dims}
            for s in all_specs()
        ],
    }
