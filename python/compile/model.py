"""Layer-2 JAX model: the CLS / KF compute graphs lowered to HLO artifacts.

Each public ``*_fn`` below is jitted and AOT-lowered by aot.py at the fixed
shape buckets in shapes.py, then executed from the rust coordinator through
PJRT. They compose the Layer-1 Pallas kernels with XLA-native factorizations.

Conventions (shared with rust/src/runtime/):
  * dtype is f64 end-to-end (the paper's 1e-11 accuracy claims require it);
  * row padding: padded rows carry d = 0 (and h = 0, rvar = 1 for KF rows) —
    exact no-ops;
  * column padding: padded columns carry diag_reg = 1 and reg_rhs = 0, so the
    padded solution entries are exactly 0 and the true block is untouched;
  * every function returns a tuple (lowered with return_tuple=True, unpacked
    with to_tupleN on the rust side).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import at_db, matvec, outer_update, weighted_gram

jax.config.update("jax_enable_x64", True)


def assemble_fn(a, d, diag_reg):
    """Assemble the local normal matrix G = A^T D A + diag(diag_reg).

    Runs once per subdomain per DyDD epoch (the matrix does not change
    across Schwarz iterations — only the right-hand side does). The O(M n^2)
    gram is the L1 Pallas kernel; the O(n^3)-once Cholesky factorization of
    the returned G happens natively on the rust side (L3) — the HLO
    Cholesky expander of the target runtime (xla_extension 0.5.1 CPU) is a
    scalar loop ~300x slower than a native factorization, see
    EXPERIMENTS.md §Perf.

    diag_reg carries: mu on overlap columns (the O_{1,2} regularization of
    eqs. 25-26), 1.0 on padded columns, 0 elsewhere.
    """
    g = weighted_gram(a, d) + jnp.diag(diag_reg)
    return (g,)


def solve_fn(a, d, b_eff, reg_rhs):
    """Schwarz-iteration right-hand side: c = A^T D b_eff + reg_rhs.

    The O(M n) weighted projection is the L1 Pallas at_db kernel; the
    O(n^2) triangular back-substitutions against the epoch's Cholesky
    factor run natively on the rust side (same rationale as assemble_fn).

    b_eff = b - A_neighbour x_neighbour (eq. 24) is assembled natively by
    the worker (halo matvec, O(M) — the halo coupling is sparse); reg_rhs
    carries mu * x_other on overlap columns (eqs. 25-26), 0 on padding.
    """
    c = at_db(a, d, b_eff) + reg_rhs
    return (c,)


def kf_chunk_fn(x, p, hrows, rvars, ys):
    """Sequential VAR-KF: process `chunk` observation rows by rank-1 updates.

    The paper's reference algorithm (§2.1): for each row h with variance
    rvar and datum y,
        w = P h;  s = h^T w + rvar;  k = w / s
        x <- x + k (y - h^T x);  P <- (I - k h^T) P = P - k w^T.
    The O(n^2) matvec and the fused outer-product update are the L1 Pallas
    kernels. Padded rows (h = 0, rvar = 1, y = 0) are exact no-ops.
    """

    def step(carry, inp):
        x, p = carry
        h, rvar, y = inp
        w = matvec(p, h)
        s = h @ w + rvar
        k = w / s
        x = x + k * (y - h @ x)
        p = outer_update(p, k, w)
        return (x, p), ()

    (x, p), _ = lax.scan(step, (x, p), (hrows, rvars, ys))
    return (x, p)


def kf_predict_fn(x, p, mmat, qdiag):
    """KF Predictor phase (eqs. 5-6): x' = M x, P' = M P M^T + Q.

    Dense n^3 matmuls — left to XLA's native gemm (no Pallas win on CPU, and
    on TPU the MXU path is exactly this). Q is diagonal (model error).
    """
    xp = mmat @ x
    pp = mmat @ p @ mmat.T + jnp.diag(qdiag)
    return (xp, pp)


def cls_full_fn(a, d, b, diag_reg):
    """Global CLS reference solve (eqs. 18-19) via gram + Cholesky.

    Used to compute error_DD-DA = ||x_KF - x_DD-DA|| (Table 11 / Figure 5)
    without trusting either decomposed path.
    """
    g = weighted_gram(a, d) + jnp.diag(diag_reg)
    l = jnp.linalg.cholesky(g)
    c = at_db(a, d, b)
    x = jax.scipy.linalg.cho_solve((l, True), c)
    return (x,)


def make_example_args(spec):
    """ShapeDtypeStructs matching an ArtifactSpec — the AOT lowering inputs."""
    f64 = jnp.float64
    k, dims = spec.kind, spec.dims
    if k == "assemble":
        m, n = dims["m"], dims["nloc"]
        return (
            jax.ShapeDtypeStruct((m, n), f64),
            jax.ShapeDtypeStruct((m,), f64),
            jax.ShapeDtypeStruct((n,), f64),
        )
    if k == "solve":
        m, n = dims["m"], dims["nloc"]
        return (
            jax.ShapeDtypeStruct((m, n), f64),
            jax.ShapeDtypeStruct((m,), f64),
            jax.ShapeDtypeStruct((m,), f64),
            jax.ShapeDtypeStruct((n,), f64),
        )
    if k == "kf_chunk":
        n, c = dims["n"], dims["chunk"]
        return (
            jax.ShapeDtypeStruct((n,), f64),
            jax.ShapeDtypeStruct((n, n), f64),
            jax.ShapeDtypeStruct((c, n), f64),
            jax.ShapeDtypeStruct((c,), f64),
            jax.ShapeDtypeStruct((c,), f64),
        )
    if k == "kf_predict":
        n = dims["n"]
        return (
            jax.ShapeDtypeStruct((n,), f64),
            jax.ShapeDtypeStruct((n, n), f64),
            jax.ShapeDtypeStruct((n, n), f64),
            jax.ShapeDtypeStruct((n,), f64),
        )
    if k == "cls_full":
        m, n = dims["m"], dims["n"]
        return (
            jax.ShapeDtypeStruct((m, n), f64),
            jax.ShapeDtypeStruct((m,), f64),
            jax.ShapeDtypeStruct((m,), f64),
            jax.ShapeDtypeStruct((n,), f64),
        )
    raise ValueError(f"unknown artifact kind {k!r}")


FUNCTIONS = {
    "assemble": assemble_fn,
    "solve": solve_fn,
    "kf_chunk": kf_chunk_fn,
    "kf_predict": kf_predict_fn,
    "cls_full": cls_full_fn,
}
