"""L2 model functions vs numpy oracles: solve correctness, padding semantics,
KF-vs-CLS equivalence (the identity the whole paper rests on)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _problem(rng, m, n, obs_rows=None):
    """A well-posed CLS instance: state rows (identity-ish) + obs rows."""
    a = rng.standard_normal((m, n)) * 0.1
    a[:n, :n] += np.eye(n)
    d = rng.random(m) + 0.5
    b = rng.standard_normal(m)
    return jnp.asarray(a), jnp.asarray(d), jnp.asarray(b)


def _local_solve(a, d, b, reg, reg_rhs=None):
    """The full local solve as the rust side performs it: the assemble and
    solve ARTIFACTS produce G and c; the O(n^3)-once factorization and the
    O(n^2) back-substitution run natively (here: numpy stands in)."""
    n = a.shape[1]
    (g,) = model.assemble_fn(a, d, reg)
    (c,) = model.solve_fn(a, d, b, reg_rhs if reg_rhs is not None else jnp.zeros(n))
    return jnp.asarray(np.linalg.solve(np.asarray(g), np.asarray(c)))


def test_assemble_solve_roundtrip():
    rng = np.random.default_rng(0)
    m, n = 96, 32
    a, d, b = _problem(rng, m, n)
    x = _local_solve(a, d, b, jnp.zeros(n))
    want = ref.cls_solve(a, d, b, jnp.zeros(n))
    np.testing.assert_allclose(x, want, rtol=1e-10, atol=1e-10)


def test_column_padding_is_exact():
    """Padded columns (diag_reg = 1) yield x_pad = 0 and do not perturb the
    true block — the invariant the rust bucket-picker relies on."""
    rng = np.random.default_rng(1)
    m, n, n_pad = 96, 24, 32
    a, d, b = _problem(rng, m, n)
    a_pad = jnp.concatenate([a, jnp.zeros((m, n_pad - n))], axis=1)
    reg_pad = jnp.concatenate([jnp.zeros(n), jnp.ones(n_pad - n)])
    x_pad = _local_solve(a_pad, d, b, reg_pad)
    want = ref.cls_solve(a, d, b, jnp.zeros(n))
    np.testing.assert_allclose(x_pad[:n], want, rtol=1e-10, atol=1e-10)
    np.testing.assert_array_equal(x_pad[n:], 0.0)


def test_row_padding_is_exact():
    rng = np.random.default_rng(2)
    m, n, m_pad = 64, 16, 96
    a, d, b = _problem(rng, m, n)
    a_big = jnp.concatenate([a, jnp.asarray(rng.standard_normal((m_pad - m, n)))])
    d_big = jnp.concatenate([d, jnp.zeros(m_pad - m)])
    b_big = jnp.concatenate([b, jnp.asarray(rng.standard_normal(m_pad - m))])
    x = _local_solve(a_big, d_big, b_big, jnp.zeros(n))
    want = ref.cls_solve(a, d, b, jnp.zeros(n))
    np.testing.assert_allclose(x, want, rtol=1e-10, atol=1e-10)


def test_kf_chunk_equals_cls_solution():
    """VAR-KF processing all rows sequentially must reproduce the CLS
    normal-equations solution (the §2 KF <-> variational equivalence)."""
    rng = np.random.default_rng(3)
    n, m_obs = 16, 48
    h0 = np.eye(n) + 0.1 * rng.standard_normal((n, n))
    y0 = rng.standard_normal(n)
    r0 = rng.random(n) + 0.5
    h1 = rng.standard_normal((m_obs, n))
    y1 = rng.standard_normal(m_obs)
    r1 = rng.random(m_obs) + 0.5

    # KF: init from the state system, then rank-1 updates over observations.
    g0 = h0.T @ np.diag(r0) @ h0
    p = jnp.asarray(np.linalg.inv(g0))
    x = jnp.asarray(np.linalg.solve(g0, h0.T @ (r0 * y0)))
    (x, p) = model.kf_chunk_fn(
        x, p, jnp.asarray(h1), jnp.asarray(1.0 / r1), jnp.asarray(y1)
    )

    # CLS: stacked normal equations.
    a = np.concatenate([h0, h1])
    d = np.concatenate([r0, r1])
    b = np.concatenate([y0, y1])
    want = ref.cls_solve(jnp.asarray(a), jnp.asarray(d), jnp.asarray(b), jnp.zeros(n))
    np.testing.assert_allclose(x, want, rtol=1e-9, atol=1e-9)


def test_kf_chunk_padded_rows_are_noops():
    rng = np.random.default_rng(4)
    n, c = 8, 8
    p0 = np.eye(n) * 2.0
    x0 = rng.standard_normal(n)
    h = np.zeros((c, n))
    h[0] = rng.standard_normal(n)
    rvar = np.ones(c)
    y = np.zeros(c)
    y[0] = 1.3
    x, p = model.kf_chunk_fn(
        jnp.asarray(x0),
        jnp.asarray(p0),
        jnp.asarray(h),
        jnp.asarray(rvar),
        jnp.asarray(y),
    )
    xw, pw = ref.kf_rank1_step(
        jnp.asarray(x0), jnp.asarray(p0), jnp.asarray(h[0]), 1.0, 1.3
    )
    np.testing.assert_allclose(x, xw, rtol=1e-12)
    np.testing.assert_allclose(p, pw, rtol=1e-12)


def test_kf_predict():
    rng = np.random.default_rng(5)
    n = 12
    x = rng.standard_normal(n)
    p = rng.standard_normal((n, n))
    p = p @ p.T
    mmat = rng.standard_normal((n, n))
    q = rng.random(n)
    xp, pp = model.kf_predict_fn(
        jnp.asarray(x), jnp.asarray(p), jnp.asarray(mmat), jnp.asarray(q)
    )
    np.testing.assert_allclose(xp, mmat @ x, rtol=1e-12)
    np.testing.assert_allclose(pp, mmat @ p @ mmat.T + np.diag(q), rtol=1e-12)


def test_cls_full_matches_dense_solve():
    rng = np.random.default_rng(6)
    a, d, b = _problem(rng, 96, 32)
    reg = jnp.zeros(32)
    (x,) = model.cls_full_fn(a, d, b, reg)
    np.testing.assert_allclose(x, ref.cls_solve(a, d, b, reg), rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("kind", sorted(model.FUNCTIONS))
def test_example_args_cover_all_kinds(kind):
    from compile import shapes

    spec = next(s for s in shapes.all_specs() if s.kind == kind)
    args = model.make_example_args(spec)
    assert all(a.dtype == jnp.float64 for a in args)
