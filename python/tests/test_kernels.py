"""L1 Pallas kernels vs pure-jnp oracles (ref.py) — the core numeric signal.

hypothesis sweeps shapes, dtypes, block sizes and weight patterns; every
kernel must match its oracle to tight f64 tolerances.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    at_db,
    matvec,
    outer_update,
    ref,
    weighted_gram,
    weighted_residual_sq,
)

DIMS = st.sampled_from([8, 16, 24, 32, 64, 128])
SEEDS = st.integers(min_value=0, max_value=2**32 - 1)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape))


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, seed=SEEDS)
def test_weighted_gram_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, n)
    d = jnp.asarray(rng.random(m))
    got = weighted_gram(a, d)
    np.testing.assert_allclose(got, ref.weighted_gram(a, d), rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, seed=SEEDS)
def test_at_db_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, n)
    d = jnp.asarray(rng.random(m))
    r = _rand(rng, m)
    np.testing.assert_allclose(
        at_db(a, d, r), ref.at_db(a, d, r), rtol=1e-12, atol=1e-12
    )


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, seed=SEEDS)
def test_matvec_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, n)
    x = _rand(rng, n)
    np.testing.assert_allclose(matvec(a, x), ref.matvec(a, x), rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(n=DIMS, seed=SEEDS)
def test_outer_update_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    p = _rand(rng, n, n)
    k = _rand(rng, n)
    w = _rand(rng, n)
    np.testing.assert_allclose(
        outer_update(p, k, w), ref.outer_update(p, k, w), rtol=1e-12, atol=1e-12
    )


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, seed=SEEDS)
def test_weighted_residual_sq_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, n)
    x = _rand(rng, n)
    b = _rand(rng, m)
    d = jnp.asarray(rng.random(m))
    got = weighted_residual_sq(a, x, b, d)[0]
    np.testing.assert_allclose(
        got, ref.weighted_residual_sq(a, x, b, d), rtol=1e-11, atol=1e-11
    )


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 32), (64, 16), (128, 128)])
def test_gram_block_sweep(bm, bn):
    """Result must be identical (up to fp) for any legal block shape."""
    rng = np.random.default_rng(7)
    a = _rand(rng, 128, 128)
    d = jnp.asarray(rng.random(128))
    want = ref.weighted_gram(a, d)
    got = weighted_gram(a, d, block_m=bm, block_n=bn)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_gram_zero_weight_rows_are_noops():
    """Row padding semantics: d = 0 rows must contribute exactly nothing."""
    rng = np.random.default_rng(3)
    a = _rand(rng, 64, 32)
    d = jnp.asarray(rng.random(64))
    d_pad = jnp.concatenate([d, jnp.zeros(64)])
    a_pad = jnp.concatenate([a, jnp.asarray(rng.standard_normal((64, 32)))])
    np.testing.assert_array_equal(weighted_gram(a_pad, d_pad), weighted_gram(a, d))


def test_f32_also_supported():
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((32, 16)), dtype=jnp.float32)
    d = jnp.asarray(rng.random(32), dtype=jnp.float32)
    got = weighted_gram(a, d)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, ref.weighted_gram(a, d), rtol=1e-5)
