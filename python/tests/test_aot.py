"""AOT pipeline: HLO text is produced, parses as HLO, manifest is complete,
and the lowered computation has the right parameter arity."""

import json
import pathlib

import pytest

from compile import aot, model, shapes


def test_manifest_covers_all_specs():
    man = shapes.manifest_dict()
    names = {a["name"] for a in man["artifacts"]}
    assert len(names) == len(man["artifacts"]), "duplicate artifact names"
    for s in shapes.all_specs():
        assert s.name in names
    assert man["dtype"] == "f64"


def test_bucket_shapes_divide_by_blocks():
    """Every bucket must be tileable by choose_blocks' picks."""
    from compile.kernels import choose_blocks

    for m, n in shapes.ASSEMBLE_PAIRS:
        bm, bn = choose_blocks(m, n)
        assert m % bm == 0 and n % bn == 0


@pytest.mark.parametrize(
    "spec",
    [s for s in shapes.all_specs() if s.dims.get("m", 1e9) <= 256],
    ids=lambda s: s.name,
)
def test_small_specs_lower_to_parseable_hlo(spec, tmp_path):
    text = aot.lower_spec(spec)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # Parameter arity must match make_example_args.
    n_params = len(model.make_example_args(spec))
    assert text.count("parameter(") >= n_params


def test_aot_main_skips_up_to_date(tmp_path):
    out = str(tmp_path / "arts")
    assert aot.main(["--out-dir", out, "--only", "assemble_m128_n32"]) == 0
    p = pathlib.Path(out) / "assemble_m128_n32.hlo.txt"
    assert p.exists()
    mtime = p.stat().st_mtime_ns
    # manifest written only on full runs; write one so fingerprint matches
    man = shapes.manifest_dict()
    man["fingerprint"] = aot.source_fingerprint()
    (pathlib.Path(out) / "manifest.json").write_text(json.dumps(man))
    assert aot.main(["--out-dir", out, "--only", "assemble_m128_n32"]) == 0
    assert p.stat().st_mtime_ns == mtime, "should have been skipped"


def test_fingerprint_stable():
    assert aot.source_fingerprint() == aot.source_fingerprint()
