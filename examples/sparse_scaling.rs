//! Sparse end-to-end scaling smoke test: the CG backend runs the full
//! DyDD → parallel DD-KF pipeline on a 128×128 grid (16 384 unknowns) —
//! a scale where the dense local path (O(m·n²) assembly + O(n³)
//! factorization, O(n²) covariance in the KF baseline) is already
//! infeasible — and is cross-checked two ways:
//!
//!  1. a 32×32 *probe* of the same gaussian_blob scenario, small enough
//!     for the sequential-KF reference: CG's analysis must agree to the
//!     usual fp-roundoff level;
//!  2. at 128×128, where no dense reference exists, the sparse
//!     normal-equations residual ‖AᵀD(b − Ax)‖/‖AᵀDb‖ (one O(nnz) pass
//!     through the `RowProvider` rows) certifies optimality directly.
//!
//!   cargo run --release --example sparse_scaling

use dydd_da::cls::RowProvider;
use dydd_da::config::ExperimentConfig;
use dydd_da::coordinator::{run_parallel, SolverBackend};
use dydd_da::domain2d::{BoxPartition, ObsLayout2d};
use dydd_da::harness::pipeline::maybe_rebalance;
use dydd_da::harness::run_experiment;
use dydd_da::util::timer::fmt_secs;

fn blob_config(n: usize, m: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("sparse-scaling-{n}");
    cfg.dim = 2;
    cfg.n = n;
    cfg.m = m;
    cfg.px = 2;
    cfg.py = 2;
    cfg.layout2d = ObsLayout2d::GaussianBlob;
    cfg.backend = SolverBackend::Cg;
    cfg.seed = 42;
    cfg
}

fn main() -> anyhow::Result<()> {
    // --- 32×32 probe: CG vs the sequential-KF reference -----------------
    println!("== 32x32 probe (CG vs sequential-KF reference) ==");
    let cfg = blob_config(32, 600);
    let rep = run_experiment(&cfg, true)?;
    let err = rep.error_dd_da.expect("probe runs the baseline");
    println!(
        "  iters={} converged={}{} error_DD-DA={err:.2e} E={:.3}",
        rep.iters,
        rep.converged,
        if rep.stalled { " (stalled)" } else { "" },
        rep.balance().unwrap_or(f64::NAN),
    );
    assert!(rep.converged || rep.stalled, "probe solve diverged");
    assert!(err <= 1e-8, "probe: CG vs KF reference = {err:e}");

    // --- 128×128: the grid the dense path cannot touch ------------------
    println!("== 128x128 gaussian_blob (16 384 unknowns, CG backend) ==");
    let cfg = blob_config(128, 3000);
    let prob = cfg.build_problem2d();
    let geom = cfg.box_geometry();
    let part0 = BoxPartition::uniform(cfg.n, cfg.n, cfg.px, cfg.py);
    let (part, dydd) = maybe_rebalance(&geom, &part0, &prob.obs, true)?;
    if let Some(d) = &dydd {
        println!("  DyDD: E = {:.3} (migrations applied)", d.balance());
    }
    let out = run_parallel(&geom, &prob, &part, &cfg.run_config())?;
    println!(
        "  iters={} converged={}{} T^p_crit={}",
        out.iters,
        out.converged,
        if out.stalled { " (stalled)" } else { "" },
        fmt_secs(out.t_critical.as_secs_f64()),
    );
    assert!(out.converged || out.stalled, "128x128 solve diverged");

    // Dense-free optimality certificate: the analysis satisfies the global
    // normal equations to (near-)roundoff.
    let res = prob.normal_residual(&out.x);
    println!("  sparse normal-equations residual = {res:.2e}");
    assert!(res <= 1e-6, "128x128: normal residual {res:e} too large");

    println!("sparse_scaling OK");
    Ok(())
}
