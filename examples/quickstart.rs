//! Quickstart: the whole DyDD / DD-KF pipeline in ~40 lines of user code.
//!
//!   cargo run --release --example quickstart
//!
//! Builds a CLS data-assimilation problem with clustered (imbalanced)
//! observations, rebalances the decomposition with DyDD, solves it in
//! parallel with DD-KF, and checks the result against the sequential
//! Kalman filter.

use dydd_da::config::ExperimentConfig;
use dydd_da::domain::ObsLayout;
use dydd_da::harness::run_experiment;

fn main() -> anyhow::Result<()> {
    // 1. Describe the experiment (see configs/ for the TOML equivalent).
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.n = 512; // mesh size (unknowns)
    cfg.m = 400; // observations
    cfg.p = 4; // subdomains / workers
    cfg.layout = ObsLayout::Cluster; // spatially clustered -> imbalanced

    // 2. Run: DyDD rebalance -> parallel DD-KF -> sequential KF baseline.
    let rep = run_experiment(&cfg, true)?;

    // 3. Inspect.
    let dydd = rep.dydd.as_ref().expect("dydd ran");
    println!("observation census before : {:?}", dydd.dydd.l_in);
    println!("observation census after  : {:?}", dydd.census_after);
    println!("load balance E            : {:.3}", dydd.balance());
    println!("schwarz iterations        : {} (converged: {})", rep.iters, rep.converged);
    println!(
        "error vs sequential KF    : {:.2e}   (paper reports ~1e-11)",
        rep.error_dd_da.unwrap()
    );
    println!(
        "T^1 = {:.3}s   T^p_wall = {:.3}s   T^p_sim = {:.3}s   S^p_sim = {:.2}",
        rep.t_sequential.unwrap().as_secs_f64(),
        rep.t_parallel.as_secs_f64(),
        rep.t_critical.as_secs_f64(),
        rep.speedup_sim().unwrap()
    );
    assert!(rep.error_dd_da.unwrap() < 1e-9, "DD must reproduce the KF estimate");
    println!("quickstart OK");
    Ok(())
}
