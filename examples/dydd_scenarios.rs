//! DyDD walkthrough: replays the paper's §5 eight-subdomain example
//! (Figures 1-4) step by step, then every Example 1/2 case from §6.
//!
//!   cargo run --release --example dydd_scenarios

use dydd_da::dydd::{balance, repair, schedule_once, DyddParams};
use dydd_da::graph::{laplacian_solve, Graph};
use dydd_da::harness::scenarios;

fn main() -> anyhow::Result<()> {
    // ---- The §5 walkthrough (Figures 1-4) --------------------------------
    println!("== Paper §5 walkthrough: 8 subdomains, loads after repair ==");
    let g = Graph::paper_example();
    let loads = vec![5usize, 4, 6, 2, 5, 3, 5, 2]; // Figure 1(b)
    let avg = loads.iter().sum::<usize>() as f64 / 8.0;
    println!("graph      : {} edges, max degree {}", g.num_edges(), g.max_degree());
    println!("loads      : {loads:?}  (average {avg})");

    // Scheduling step: the Laplacian system of eq. (30).
    let b: Vec<f64> = loads.iter().map(|&l| l as f64 - avg).collect();
    let lambda = laplacian_solve(&g, &b)?;
    println!("lambda     : {:?}", lambda.iter().map(|x| (x * 100.0).round() / 100.0).collect::<Vec<_>>());
    let sched = schedule_once(&g, &loads)?;
    for (i, j, d) in &sched {
        if *d != 0 {
            println!("  migrate {:+} obs across edge ({}, {})", d, i + 1, j + 1);
        }
    }
    let out = balance(&g, &loads, &DyddParams::default())?;
    println!("l_fin      : {:?}  (E = {:.3}, {} iterations)\n", out.l_fin, out.balance(), out.iters);

    // ---- DD (repair) step in isolation -----------------------------------
    println!("== DD step: empty-subdomain repair (Table 2 shape) ==");
    let chain = Graph::chain(2);
    let mut l = vec![1500usize, 0];
    repair(&chain, &mut l)?;
    println!("l_in = [1500, 0]  ->  l_r = {l:?}\n");

    // ---- Every §6 scenario -------------------------------------------------
    for (name, sc) in [
        ("Example 1 Case 1", scenarios::example1(1)),
        ("Example 1 Case 2", scenarios::example1(2)),
        ("Example 2 Case 1", scenarios::example2(1)),
        ("Example 2 Case 2", scenarios::example2(2)),
        ("Example 2 Case 3", scenarios::example2(3)),
        ("Example 2 Case 4", scenarios::example2(4)),
        ("Example 3 (p=8)", scenarios::example3(8)),
        ("Example 4 (p=8)", scenarios::example4(8)),
    ] {
        let out = balance(&sc.graph, &sc.l_in, &DyddParams::default())?;
        println!(
            "{name:18} l_in = {:?} -> l_fin = {:?}  E = {:.3}",
            out.l_in, out.l_fin, out.balance()
        );
    }
    println!("\ndydd_scenarios OK");
    Ok(())
}
