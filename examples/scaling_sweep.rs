//! Scaling sweep: DD-KF accuracy and simulated-parallel efficiency across
//! subdomain counts and observation layouts (the Examples 3/4 axis of the
//! paper, on configurable problem sizes).
//!
//!   cargo run --release --example scaling_sweep [-- --n 512 --m 400]

use dydd_da::config::ExperimentConfig;
use dydd_da::domain::ObsLayout;
use dydd_da::harness::run_experiment;
use dydd_da::util::timer::fmt_secs;
use dydd_da::util::Table;

fn arg<T: std::str::FromStr>(key: &str, default: T) -> T {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n: usize = arg("--n", 512);
    let m: usize = arg("--m", 400);

    for layout in [ObsLayout::Uniform, ObsLayout::Cluster, ObsLayout::LeftPacked] {
        let mut t = Table::new(
            &format!("scaling sweep — layout {layout:?}, n = {n}, m = {m}"),
            &["p", "E (dydd)", "iters", "T^p_sim", "S^p_sim", "E^p_sim", "error_DD-DA"],
        );
        for p in [2usize, 4, 8, 16] {
            if n / p < 8 {
                continue;
            }
            let mut cfg = ExperimentConfig::default();
            cfg.n = n;
            cfg.m = m;
            cfg.p = p;
            cfg.layout = layout;
            let rep = run_experiment(&cfg, true)?;
            t.row(&[
                p.to_string(),
                format!("{:.3}", rep.balance().unwrap()),
                rep.iters.to_string(),
                fmt_secs(rep.t_critical.as_secs_f64()),
                format!("{:.2}", rep.speedup_sim().unwrap()),
                format!("{:.2}", rep.efficiency_sim().unwrap()),
                format!("{:.1e}", rep.error_dd_da.unwrap()),
            ]);
            assert!(rep.error_dd_da.unwrap() < 1e-8, "accuracy must hold at any p");
        }
        println!("{}", t.render());
    }
    println!("scaling_sweep OK");
    Ok(())
}
