//! Strong-scaling sweep: *measured* wall-clock next to the simulated
//! critical path, across worker counts p = 1..8, grids up to 512², dense
//! (native Cholesky) vs sparse (cg) local solvers, and warm vs cold
//! epochs on the persistent pool.
//!
//!   cargo run --release --example scaling_sweep              # standard sweep
//!   cargo run --release --example scaling_sweep -- --full    # up to 512²
//!   cargo run --release --example scaling_sweep -- --smoke   # CI assertions
//!
//! The smoke mode is the CI gate: p ∈ {1, 2, 4} on a 128² grid with the
//! cg backend, asserting (a) the analysis with kernel threads = 4 is
//! bitwise-identical to kernel threads = 1 (the banded deterministic
//! reduction contract), likewise with batched dispatch forced on vs off
//! (the same contract for same-shape block grouping), and (b) the
//! wall-clock speedup from parallel
//! execution at p = 4 is real (> 1): the aggregate worker busy time
//! exceeds the measured wall-clock, which is only possible when workers
//! genuinely overlap in time. The gate deliberately does *not* compare
//! against p = 1 cold wall: a single block has no interfaces and
//! converges in ~2 outer sweeps, so p > 1 pays an interface-iteration
//! penalty that is a property of zero-overlap Schwarz, not of the
//! parallel runtime (the sweep table reports that ratio as data).
//!
//! Kernel threads (`--threads` / DYDD_THREADS) stay at 1 during the
//! sweep: worker-level parallelism is the measured axis, and mixing the
//! two would double-subscribe the cores.

// lint:allow-file(no-wall-clock-in-sim) measured wall-clock is the point here
use dydd_da::coordinator::{BlockTask, SolverBackend, WorkerPool};
use dydd_da::ddkf::SchwarzOptions;
use dydd_da::decomp::{blocks_of, phases_of, BlockEpoch, BoxGeometry, Geometry};
use dydd_da::util::timer::fmt_secs;
use dydd_da::util::{Rng, Table};
use std::time::{Duration, Instant};

fn has(key: &str) -> bool {
    std::env::args().any(|a| a == key)
}

fn arg<T: std::str::FromStr>(key: &str, default: T) -> T {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Subdomain grid for p workers (px · py = p, as square as p allows).
fn grid_of(p: usize) -> (usize, usize) {
    match p {
        1 => (1, 1),
        2 => (2, 1),
        4 => (2, 2),
        8 => (4, 2),
        _ => (p, 1),
    }
}

/// One measured cell of the sweep.
struct Cell {
    iters: usize,
    converged: bool,
    t_cold: Duration,
    t_warm: Duration,
    t_critical: Duration,
    /// Aggregate per-worker solve time of the cold epoch; > `t_cold`
    /// exactly when workers overlapped in real time.
    busy: Duration,
    x: Vec<f64>,
}

/// Solve one (grid, backend, p) configuration twice on a persistent pool:
/// cold (fresh extraction + factorization of every block) and warm
/// (Retain every block, warm-started from the cached solutions) — both
/// under real wall-clock, with the simulated critical path alongside.
fn run_cell(n_axis: usize, backend: SolverBackend, p: usize, seed: u64) -> anyhow::Result<Cell> {
    let (px, py) = grid_of(p);
    let geom = BoxGeometry::new(n_axis, px, py);
    let mut rng = Rng::new(seed);
    let obs = geom.static_obs(8 * n_axis, &mut rng);
    let prob = geom.make_problem(geom.background(), obs);
    let part = geom.initial_partition();
    let opts = SchwarzOptions::default();
    let n = geom.n_unknowns();

    let mut pool = WorkerPool::new(p, backend, "artifacts".into());
    let epochs = vec![BlockEpoch::default(); p];

    let t0 = Instant::now();
    let blocks = blocks_of(&geom, &prob, &part, opts.overlap);
    let phases = phases_of(&geom, &blocks, &part);
    let tasks: Vec<BlockTask> = blocks.into_iter().map(BlockTask::Extract).collect();
    let (cold, _) = pool.solve_blocks_incremental(n, tasks, &epochs, &phases, &opts, false)?;
    let t_cold = t0.elapsed();

    let tasks: Vec<BlockTask> = (0..p).map(|_| BlockTask::Retain).collect();
    let t0 = Instant::now();
    let (warm, _) = pool.solve_blocks_incremental(n, tasks, &epochs, &phases, &opts, true)?;
    let t_warm = t0.elapsed();
    anyhow::ensure!(
        warm.converged || warm.stalled,
        "warm re-solve diverged on {n_axis}² p={p}"
    );

    Ok(Cell {
        iters: cold.iters,
        converged: cold.converged,
        t_cold,
        t_warm,
        t_critical: cold.t_critical,
        busy: cold.worker_busy.iter().sum(),
        x: cold.x,
    })
}

/// The batched-dispatch determinism gate: the same solve with the batch
/// mode forced off vs on must produce bitwise-identical analyses (batched
/// kernels band across members; padding is storage-only).
fn assert_batch_bitwise(n_axis: usize, p: usize, seed: u64) -> anyhow::Result<()> {
    use dydd_da::util::batch::{set_batch_mode, BatchMode};
    set_batch_mode(BatchMode::Off);
    let off = run_cell(n_axis, SolverBackend::Native, p, seed)?;
    set_batch_mode(BatchMode::On);
    let on = run_cell(n_axis, SolverBackend::Native, p, seed)?;
    set_batch_mode(BatchMode::Auto);
    anyhow::ensure!(off.x.len() == on.x.len(), "analysis length changed");
    anyhow::ensure!(off.iters == on.iters, "iteration count changed under batching");
    for (i, (a, b)) in off.x.iter().zip(&on.x).enumerate() {
        anyhow::ensure!(
            a.to_bits() == b.to_bits(),
            "analysis[{i}] differs across batch modes: {a:e} vs {b:e}"
        );
    }
    println!(
        "bitwise check OK: {n_axis}² native p={p}, batch off vs on identical \
         ({} unknowns)",
        off.x.len()
    );
    Ok(())
}

/// The banded-kernel determinism gate: the same native-backend solve with
/// kernel threads 1 vs 4 must produce bitwise-identical analyses (the
/// dense gram/matmul path is the one the threads knob parallelizes).
fn assert_threads_bitwise(n_axis: usize, p: usize, seed: u64) -> anyhow::Result<()> {
    dydd_da::util::threads::set_threads(1);
    let serial = run_cell(n_axis, SolverBackend::Native, p, seed)?;
    dydd_da::util::threads::set_threads(4);
    let parallel = run_cell(n_axis, SolverBackend::Native, p, seed)?;
    dydd_da::util::threads::set_threads(1);
    anyhow::ensure!(serial.x.len() == parallel.x.len(), "analysis length changed");
    for (i, (a, b)) in serial.x.iter().zip(&parallel.x).enumerate() {
        anyhow::ensure!(
            a.to_bits() == b.to_bits(),
            "analysis[{i}] differs across kernel thread counts: {a:e} vs {b:e}"
        );
    }
    println!(
        "bitwise check OK: {n_axis}² native p={p}, threads 1 vs 4 identical \
         ({} unknowns)",
        serial.x.len()
    );
    Ok(())
}

fn smoke() -> anyhow::Result<()> {
    // (a) Deterministic parallel kernels, where the dense gram actually
    // crosses the parallel-gate size — and the batched-dispatch contract
    // on the same cell.
    assert_threads_bitwise(64, 4, 7)?;
    assert_batch_bitwise(64, 8, 7)?;

    // (b) Real parallel execution on 128² with the sparse backend.
    let n_axis = 128;
    let mut overlap_p4 = None;
    for p in [1usize, 2, 4] {
        let cell = run_cell(n_axis, SolverBackend::Cg, p, 7)?;
        anyhow::ensure!(
            cell.converged,
            "smoke solve failed to converge at p={p} ({} iters)",
            cell.iters
        );
        println!(
            "smoke: {n_axis}² cg p={p}: iters={} t_wall={} t_warm={} t_crit={} busy={}",
            cell.iters,
            fmt_secs(cell.t_cold.as_secs_f64()),
            fmt_secs(cell.t_warm.as_secs_f64()),
            fmt_secs(cell.t_critical.as_secs_f64()),
            fmt_secs(cell.busy.as_secs_f64()),
        );
        if p == 4 {
            overlap_p4 = Some(cell.busy.as_secs_f64() / cell.t_cold.as_secs_f64().max(1e-12));
        }
    }
    // The measured-concurrency gate: aggregate worker busy time can only
    // exceed wall-clock if the pool really ran workers at the same time,
    // so this is wall-clock speedup from parallel execution — robust to
    // the interface-iteration penalty that p > 1 pays over p = 1.
    let speedup = overlap_p4.expect("p=4 cell ran");
    anyhow::ensure!(
        speedup > 1.0,
        "parallel execution at p=4 must be real: busy/wall = {speedup:.2} (<= 1 means \
         the workers never overlapped in time)"
    );
    println!("smoke: measured parallel speedup at p=4 (busy/wall): {speedup:.2}x");
    println!("scaling_sweep OK");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if has("--smoke") {
        return smoke();
    }
    let seed: u64 = arg("--seed", 7);
    let full = has("--full");
    let grids: &[usize] = if full { &[64, 128, 256, 512] } else { &[64, 128, 256] };
    // Dense local Cholesky is O((n/p)³); past 64² the per-block factors
    // dominate the sweep's runtime, so dense rows are capped there — and
    // the cap is logged, never silent.
    let dense_cap = 64;

    assert_threads_bitwise(64, 4, seed)?;
    assert_batch_bitwise(64, 8, seed)?;

    for &n_axis in grids {
        for backend in [SolverBackend::Native, SolverBackend::Cg] {
            if backend == SolverBackend::Native && n_axis > dense_cap {
                eprintln!(
                    "note: skipping dense backend on {n_axis}² (dense local Cholesky \
                     capped at {dense_cap}²; the cg rows cover this grid)"
                );
                continue;
            }
            let label = match backend {
                SolverBackend::Native => "dense",
                _ => "cg",
            };
            let mut t = Table::new(
                &format!(
                    "strong scaling — {n_axis}² grid ({} unknowns), backend {label}",
                    n_axis * n_axis
                ),
                &["p", "iters", "T_wall cold", "T_wall warm", "T^p_crit", "S_wall", "S_sim", "busy/wall"],
            );
            let mut base: Option<(f64, f64)> = None;
            for p in [1usize, 2, 4, 8] {
                let cell = run_cell(n_axis, backend, p, seed)?;
                let (w, c) = (cell.t_cold.as_secs_f64(), cell.t_critical.as_secs_f64());
                let (w1, c1) = *base.get_or_insert((w, c));
                t.row(&[
                    p.to_string(),
                    cell.iters.to_string(),
                    fmt_secs(w),
                    fmt_secs(cell.t_warm.as_secs_f64()),
                    fmt_secs(c),
                    format!("{:.2}", w1 / w.max(1e-12)),
                    format!("{:.2}", c1 / c.max(1e-12)),
                    format!("{:.2}", cell.busy.as_secs_f64() / w.max(1e-12)),
                ]);
                anyhow::ensure!(
                    cell.converged || cell.iters > 0,
                    "no iterations recorded on {n_axis}² {label} p={p}"
                );
            }
            println!("{}", t.render());
        }
    }
    println!("scaling_sweep OK");
    Ok(())
}
