//! 4D-VAR with Parallel-in-Time domain decomposition (paper §3 + §1 item
//! 4): the unknown is the whole space-time trajectory; time windows are
//! the subdomains; DyDD balances observation counts across windows.
//!
//!   cargo run --release --example fourdvar_pint

use dydd_da::cls::StateOp;
use dydd_da::ddkf::{NativeLocalSolver, SchwarzOptions};
use dydd_da::domain::{generators, Mesh1d, ObservationSet, Partition};
use dydd_da::fourd::{schwarz_solve_4d, window_census, window_partition, TrajectoryProblem};
use dydd_da::linalg::mat::dist2;
use dydd_da::util::Rng;

fn main() -> anyhow::Result<()> {
    let n = 24; // space points
    let steps = 12; // time levels -> 288 space-time unknowns
    let mesh = Mesh1d::new(n);
    let mut rng = Rng::new(7);

    // Observations pile up in the first and last quarters of the window —
    // the non-uniform-in-TIME layout the paper's conclusions call out.
    let obs: Vec<ObservationSet> = (0..steps)
        .map(|l| {
            let m = if l < 3 || l >= 9 { 20 } else { 2 };
            generators::generate(dydd_da::domain::ObsLayout::Uniform, m, &mut rng)
        })
        .collect();
    let per_level: Vec<usize> = obs.iter().map(|o| o.len()).collect();
    println!("observations per time level : {per_level:?}");

    let background = (0..n).map(|j| generators::field(j as f64 / (n - 1) as f64)).collect();
    let prob = TrajectoryProblem::new(
        mesh,
        StateOp::Tridiag { main: 0.9, off: 0.05 },
        steps,
        background,
        vec![4.0; n],
        10.0, // weak-constraint model weight (Q^-1)
        obs,
    );

    // Uniform-in-time windows vs DyDD-balanced windows.
    let windows = 4;
    let uniform = Partition::from_bounds(
        prob.n(),
        (0..=windows).map(|w| w * steps / windows * n).collect(),
    );
    let (balanced, targets) = window_partition(&prob, windows)?;
    println!("uniform window census       : {:?}", window_census(&prob, &uniform));
    println!("DyDD targets                : {targets:?}");
    println!("balanced window census      : {:?}", window_census(&prob, &balanced));

    // Solve with both partitions; the trajectory must be identical.
    let opts = SchwarzOptions { max_iters: 2000, ..SchwarzOptions::default() };
    let (x_u, it_u, conv_u) = schwarz_solve_4d(&prob, &uniform, &opts, &mut NativeLocalSolver)?;
    let (x_b, it_b, conv_b) = schwarz_solve_4d(&prob, &balanced, &opts, &mut NativeLocalSolver)?;
    anyhow::ensure!(conv_u && conv_b, "PinT Schwarz did not converge");
    let want = prob.solve_reference();
    println!(
        "uniform : {it_u} iters, error vs reference = {:.2e}",
        dist2(&x_u, &want)
    );
    println!(
        "balanced: {it_b} iters, error vs reference = {:.2e}",
        dist2(&x_b, &want)
    );
    assert!(dist2(&x_u, &want) < 1e-7);
    assert!(dist2(&x_b, &want) < 1e-7);

    // Per-window work is proportional to rows ~ (n·levels + obs): report
    // the balance improvement.
    let work = |part: &Partition| -> Vec<usize> {
        (0..windows)
            .map(|w| {
                let (lo, hi) = part.interval(w);
                prob.local_block(lo, hi).m_loc()
            })
            .collect()
    };
    println!("per-window rows (uniform)   : {:?}", work(&uniform));
    println!("per-window rows (balanced)  : {:?}", work(&balanced));
    println!("fourdvar_pint OK");
    Ok(())
}
