//! Streaming incremental assimilation smoke test: a drifting blob served
//! tick by tick, incremental (dirty-block) vs forced-cold solves.
//!
//!   cargo run --release --example stream_serve
//!
//! A Gaussian blob of observations translates across [0, 1] over K = 16
//! ticks of the native per-row drift stream. The incremental engine
//! re-extracts only the blocks the tick's delta touched and serves the
//! rest from the per-block solution cache (`RefreshB` / `Retain`), so a
//! warm tick pays a fraction of a cold tick's factorizations. The
//! assertions at the bottom are the ISSUE acceptance criteria, re-checked
//! in release mode by CI:
//!
//!   * warm ticks score cache hits (the blob never touches the far-right
//!     blocks between consecutive ticks);
//!   * the mean warm-tick wall-clock is measurably below the forced-cold
//!     mean on the same feed;
//!   * both runs converge every tick and agree on the final analysis.

use dydd_da::decomp::IntervalGeometry;
use dydd_da::domain::DriftLayout;
use dydd_da::linalg::mat::dist2;
use dydd_da::stream::{run_stream, DriftSource, StreamOptions, StreamReport};
use dydd_da::util::timer::fmt_secs;

const N: usize = 2048;
const P: usize = 8;
const M: usize = 1200;
const TICKS: usize = 16;

fn serve(geom: &IntervalGeometry, force_cold: bool) -> anyhow::Result<StreamReport> {
    let opts = StreamOptions { force_cold, ..StreamOptions::default() };
    let mut src =
        DriftSource::new(geom, M, 42, TICKS).expect("1-D drifts have a native stream");
    run_stream(geom, &mut src, &opts, |_| {})
}

fn summarize(name: &str, rep: &StreamReport) {
    println!(
        "{name:>11}: ticks={}  factorizations={}  cache_hit_mean={:.3}  \
         warm_tick_wall_mean={}",
        rep.records.len(),
        rep.total_factorizations(),
        rep.mean_cache_hit_rate(),
        fmt_secs(rep.mean_warm_tick_wall()),
    );
}

fn main() -> anyhow::Result<()> {
    println!("== streaming drifting blob: n={N}, m={M}, p={P}, K={TICKS} ==\n");
    let mut geom = IntervalGeometry::new(N, P);
    geom.drift = DriftLayout::TranslatingBlob;

    let warm = serve(&geom, false)?;
    let cold = serve(&geom, true)?;
    summarize("incremental", &warm);
    summarize("cold", &cold);

    assert!(warm.all_converged(), "an incremental tick did not converge");
    assert!(cold.all_converged(), "a cold tick did not converge");
    assert_eq!(warm.records.len(), TICKS);

    // Warm ticks must actually hit the cache: the blob lives in the left
    // half of the domain, so the right-hand blocks stay clean.
    let hits = warm.mean_cache_hit_rate();
    assert!(hits > 0.0, "no cache hits across warm ticks");
    assert!(
        warm.total_factorizations() < cold.total_factorizations(),
        "incremental run paid as many factorizations ({}) as the cold run ({})",
        warm.total_factorizations(),
        cold.total_factorizations()
    );

    // The cost argument: a warm tick re-factorizes only dirty blocks, so
    // its mean wall-clock sits below the cold mean on the same feed.
    let (wm, cm) = (warm.mean_warm_tick_wall(), cold.mean_warm_tick_wall());
    assert!(
        wm < cm,
        "warm ticks ({}) not cheaper than cold ticks ({})",
        fmt_secs(wm),
        fmt_secs(cm)
    );
    println!(
        "\nwarm/cold tick cost = {:.2} (cache_hit_mean = {hits:.3})",
        wm / cm.max(1e-12)
    );

    // Both runs assimilate the same feed to the same converged analysis.
    let err = dist2(&warm.x, &cold.x);
    assert!(err < 1e-6, "incremental and cold analyses diverged: {err:e}");

    println!("stream_serve OK");
    Ok(())
}
