//! 4-D space-time cycling smoke test: multi-cycle assimilation with
//! adaptive DyDD re-triggering on *time windows* of a stacked trajectory —
//! the scenario the dimension-generic decomposition core makes possible
//! (`cycle --dim 4` on the CLI).
//!
//!   cargo run --release --example dydd_4d
//!
//! A 12-point spatial mesh × 16 time levels (192 space-time unknowns) is
//! decomposed into 4 time windows. Across K = 8 cycles the observation
//! density drifts over the *time axis* (translating-blob profile): early
//! cycles concentrate observations in the early levels, later cycles push
//! mass towards the end of the window. DyDD re-balances the window
//! boundaries at whole-level granularity; the DD-KF analysis of each
//! cycle feeds its last level forward as the next background (forecast →
//! background chaining, like an operational 4D-Var window cascade).
//!
//! Assertions (CI runs this in release mode):
//!  * every cycle's parallel space-time analysis matches the sequential
//!    KF over the stacked trajectory to <= 1e-8 — the acceptance
//!    criterion of the dimension-generic refactor;
//!  * `never` keeps the uniform windows and its balance stays poor;
//!  * `every_cycle` re-balances all 8 cycles and holds good balance;
//!  * `threshold:0.6` re-triggers adaptively (more than once, fewer than
//!    every cycle — the drift pushes ℰ back under τ mid-run) while
//!    keeping balance far above the static decomposition.

use dydd_da::config::ExperimentConfig;
use dydd_da::domain::DriftLayout;
use dydd_da::dydd::RebalancePolicy;
use dydd_da::harness::cycles::render_cycle_table;
use dydd_da::harness::{run_cycles, CycleReport};

fn scenario(policy: RebalancePolicy) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("cycles-4d-{}", policy.name());
    cfg.dim = 4;
    cfg.n = 12;
    cfg.steps = 16;
    cfg.p = 4; // time windows
    cfg.m = 320;
    cfg.cycles = 8;
    cfg.seed = 42;
    cfg.drift = DriftLayout::TranslatingBlob; // density over the time axis
    cfg.cycle_policy = policy;
    cfg
}

fn summarize(rep: &CycleReport) {
    println!("{}", render_cycle_table(rep).render());
    println!(
        "  => rebalances {}/{}  E_final {:.3}  E_mean {:.3}  E_worst {:.3}  moved {}\n",
        rep.rebalances(),
        rep.records.len(),
        rep.final_balance(),
        rep.mean_balance(),
        rep.worst_balance(),
        rep.total_migration_volume(),
    );
}

fn main() -> anyhow::Result<()> {
    println!("== 4-D space-time cycling: n=12 x steps=16, m=320, 4 windows, K=8 ==\n");
    let never = run_cycles(&scenario(RebalancePolicy::Never), true)?;
    let every = run_cycles(&scenario(RebalancePolicy::EveryCycle), true)?;
    let thr = run_cycles(&scenario(RebalancePolicy::Threshold(0.6)), true)?;

    for rep in [&never, &every, &thr] {
        summarize(rep);
        assert!(rep.all_converged(), "{}: a cycle failed to converge", rep.name);
        for r in &rep.records {
            let err = r.error_dd_da.expect("baseline enabled");
            assert!(
                err <= 1e-8,
                "{} cycle {}: parallel space-time analysis vs sequential KF = {err:e}",
                rep.name,
                r.cycle
            );
        }
        // The report carries the full final space-time trajectory.
        assert_eq!(rep.x.len(), 12 * 16, "{}", rep.name);
    }

    // Policy semantics.
    assert_eq!(never.rebalances(), 0);
    assert_eq!(every.rebalances(), 8);
    // Adaptive re-triggering: the first cycle's uniform windows are badly
    // balanced (trigger), then the drift decays ℰ back under τ = 0.6 late
    // in the run (second trigger) — strictly fewer than every-cycle.
    // (Exact-arithmetic census simulation: 2 rebalances at seeds 42 & 7.)
    assert!(
        thr.rebalances() >= 2 && thr.rebalances() < every.rebalances(),
        "threshold rebalances = {} (want adaptive: >= 2, < {})",
        thr.rebalances(),
        every.rebalances()
    );

    // Balance quality (level-granular realization caps what any policy can
    // reach; margins from the exact census simulation).
    assert!(every.final_balance() >= 0.6, "every: E_final = {}", every.final_balance());
    assert!(never.final_balance() <= 0.45, "never: E_final = {}", never.final_balance());
    assert!(thr.worst_balance() >= 0.45, "threshold: E_worst = {}", thr.worst_balance());
    assert!(
        thr.mean_balance() >= never.mean_balance() + 0.15,
        "threshold mean E {:.3} not measurably better than static {:.3}",
        thr.mean_balance(),
        never.mean_balance()
    );

    println!("dydd_4d OK");
    Ok(())
}
