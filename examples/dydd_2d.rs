//! 2-D DyDD walkthrough: geometric rebalancing of clustered observations
//! on a box-grid decomposition of [0, 1]².
//!
//!   cargo run --release --example dydd_2d
//!
//! Three scenarios: a Gaussian blob (separable clustering), a diagonal
//! band (non-separable — per-column y-bounds are what balance it), and a
//! quadrant layout whose ¾-empty grid exercises the DD repair step.

use dydd_da::decomp::BoxGeometry;
use dydd_da::domain2d::ObsLayout2d;
use dydd_da::dydd::{balance_ratio, rebalance, DyddParams};
use dydd_da::harness::scenarios::{self, render_census_grid};
use dydd_da::util::timer::fmt_secs;

fn show_grid(label: &str, census: &[usize], px: usize, py: usize) -> anyhow::Result<()> {
    println!("{label} (E = {:.3}):", balance_ratio(census));
    print!("{}", render_census_grid(census, px, py)?);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    for (title, layout, px, py, m) in [
        ("Gaussian blob, 4x4 boxes", ObsLayout2d::GaussianBlob, 4usize, 4usize, 2000usize),
        ("Diagonal band, 4x4 boxes", ObsLayout2d::DiagonalBand, 4, 4, 2000),
        ("Quadrant (3/4 empty), 2x2 boxes", ObsLayout2d::Quadrant, 2, 2, 600),
    ] {
        println!("== {title} ==");
        let sc = scenarios::grid2d(512, px, py, m, layout, 42)?;
        let l_in = sc.census();
        show_grid("l_in ", &l_in, px, py)?;
        let geom = BoxGeometry::new(512, px, py);
        let out = rebalance(&geom, &sc.part, &sc.obs, &DyddParams::default())?;
        if let Some(lr) = &out.dydd.l_r {
            show_grid("l_r  ", lr, px, py)?;
            println!("    (DD repair step split max-load neighbours of empty boxes)");
        }
        show_grid("l_fin", &out.census_after, px, py)?;
        println!(
            "    {} scheduling iterations, {} migrations, T_DyDD = {}, T_r = {}",
            out.dydd.iters,
            out.dydd.migrations.len(),
            fmt_secs(out.dydd.t_dydd.as_secs_f64()),
            fmt_secs(out.dydd.t_repartition.as_secs_f64()),
        );
        assert_eq!(
            out.census_after.iter().sum::<usize>(),
            m,
            "migration must conserve the observation count"
        );
        println!();
    }
    println!("dydd_2d OK");
    Ok(())
}
