//! End-to-end dynamic data assimilation — the full three-layer stack on a
//! real (small) workload, proving all layers compose:
//!
//! * a 1-D advection–diffusion truth run (L3 `model`);
//! * a **reference Kalman filter** whose predict and rank-1 analysis steps
//!   execute through the AOT XLA artifacts (L2 jax + L1 Pallas kernels via
//!   PJRT) when available, natively otherwise;
//! * a **DD-KF analysis** path: every cycle the observation cluster drifts,
//!   DyDD re-balances the decomposition, and the CLS analysis problem is
//!   solved in parallel by the coordinator;
//! * a **static-DD control** (no DyDD) quantifying the load imbalance the
//!   paper's contribution removes.
//!
//!   cargo run --release --example e2e_assimilation [-- --cycles 120]
//!
//! Prints per-phase metrics and a summary; paste the summary block into
//! EXPERIMENTS.md.

use dydd_da::cls::{ClsProblem, StateOp};
use dydd_da::coordinator::{SolverBackend, WorkerPool};
use dydd_da::ddkf::SchwarzOptions;
use dydd_da::domain::{generators, Mesh1d, ObservationSet, Partition};
use dydd_da::decomp::IntervalGeometry;
use dydd_da::dydd::{rebalance, DyddParams};
use dydd_da::kf::DenseKf;
use dydd_da::linalg::Mat;
use dydd_da::model::{advection_diffusion, DynamicModel};
use dydd_da::runtime;
use dydd_da::util::Rng;
use std::time::{Duration, Instant};

fn arg<T: std::str::FromStr>(key: &str, default: T) -> T {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn rmse(a: &[f64], b: &[f64]) -> f64 {
    (a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64).sqrt()
}

struct KfEngine {
    use_pjrt: bool,
    dir: std::path::PathBuf,
}

impl KfEngine {
    /// Predict via the kf_predict artifact (L2 matmuls) when available.
    fn predict(&self, kf: &mut DenseKf, m: &Mat, q: &[f64]) -> Duration {
        let t0 = Instant::now();
        if self.use_pjrt {
            let (x, p) = runtime::with_engine(&self.dir, |eng| {
                let meta = eng.manifest().pick_kf_predict(kf.n()).expect("kf_predict bucket");
                runtime::kf_predict(eng, &meta.clone(), &kf.x, &kf.p, m, q)
            })
            .expect("pjrt predict");
            kf.x = x;
            kf.p = p;
        } else {
            kf.predict(m, q);
        }
        t0.elapsed()
    }

    /// Analysis via chunked kf_chunk artifacts (L1 Pallas matvec +
    /// fused rank-1 kernels inside a lax.scan).
    fn correct(&self, kf: &mut DenseKf, rows: &[(Vec<f64>, f64, f64)]) -> Duration {
        let t0 = Instant::now();
        if self.use_pjrt {
            let n = kf.n();
            runtime::with_engine(&self.dir, |eng| {
                let mut off = 0;
                while off < rows.len() {
                    let meta = eng
                        .manifest()
                        .pick_kf_chunk(n, rows.len() - off)
                        .expect("kf_chunk bucket")
                        .clone();
                    let take = meta.chunk.min(rows.len() - off);
                    let (x, p) = runtime::kf_chunk(eng, &meta, &kf.x, &kf.p, &rows[off..off + take])?;
                    kf.x = x;
                    kf.p = p;
                    off += take;
                }
                Ok(())
            })
            .expect("pjrt correct");
        } else {
            kf.correct_batch(rows);
        }
        t0.elapsed()
    }
}

fn obs_rows(mesh: &Mesh1d, obs: &ObservationSet) -> Vec<(Vec<f64>, f64, f64)> {
    (0..obs.len())
        .map(|k| {
            let (j, wl, wr) = obs.interp_row(mesh, k);
            let mut h = vec![0.0; mesh.n()];
            h[j] = wl;
            if wr != 0.0 {
                h[j + 1] = wr;
            }
            (h, obs.variances[k], obs.values[k])
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n: usize = arg("--n", 256);
    let cycles: usize = arg("--cycles", 120);
    let m_obs: usize = arg("--m", 160);
    let p: usize = arg("--p", 4);
    let force_native = std::env::args().any(|a| a == "--native");

    let dir = runtime::default_artifacts_dir();
    let use_pjrt = !force_native && runtime::artifacts_available(&dir);
    println!(
        "e2e: n={n} cycles={cycles} m={m_obs} p={p} backend={}",
        if use_pjrt { "pjrt (AOT XLA artifacts)" } else { "native" }
    );

    let mesh = Mesh1d::new(n);
    let model = advection_diffusion(n, 0.8, 5e-4, 0.5 / n as f64);
    let mmat = model.matrix().clone();
    let qdiag = vec![1e-6; n];
    let sigma_b = 0.08; // background error std for the DD-3DVar-style analysis
    let sigma_o = 0.05;

    let mut rng = Rng::new(2024);
    // Truth: a smooth field advected by the model + small model noise.
    let mut truth: Vec<f64> = (0..n).map(|j| generators::field(j as f64 / n as f64)).collect();

    // Reference filter (full KF through artifacts).
    let kf_engine = KfEngine { use_pjrt, dir: dir.clone() };
    let mut kf = DenseKf::from_prior(truth.clone(), &vec![1.0 / (sigma_b * sigma_b); n]);
    // Perturb the initial mean so both filters must actually work.
    for v in kf.x.iter_mut() {
        *v += rng.gaussian_with(0.0, 0.1);
    }

    // DD path state (3D-Var-style cycling with static background weights).
    let mut x_dd = kf.x.clone();
    let mut x_static = kf.x.clone();

    // One persistent pool per path: workers (and their PJRT compile
    // caches) survive all assimilation cycles.
    let backend = if use_pjrt { SolverBackend::Pjrt } else { SolverBackend::Native };
    let mut pool_dd = WorkerPool::new(p, backend, dir.clone());
    let mut pool_static = WorkerPool::new(p, backend, dir.clone());
    let opts = SchwarzOptions::default();

    let mut t_kf = Duration::ZERO;
    let mut t_dd = Duration::ZERO;
    let mut t_dydd = Duration::ZERO;
    let mut rmse_kf = 0.0;
    let mut rmse_dd = 0.0;
    let mut rmse_static = 0.0;
    let mut min_balance: f64 = 1.0;
    let mut worst_static_imbalance: f64 = 1.0;
    let mut sum_err_paths = 0.0;

    for cycle in 0..cycles {
        // --- Nature run + observations (drifting cluster). -------------
        truth = model.step(&truth);
        for v in truth.iter_mut() {
            *v += rng.gaussian_with(0.0, 1e-4);
        }
        let t01 = cycle as f64 / cycles.max(1) as f64;
        let mut obs = generators::drifting_cluster(m_obs, t01, &mut rng);
        for k in 0..obs.len() {
            let g = mesh.nearest(obs.locs[k]);
            obs.values[k] = truth[g] + rng.gaussian_with(0.0, sigma_o);
            obs.variances[k] = sigma_o * sigma_o;
        }
        let rows = obs_rows(&mesh, &obs);

        // --- Reference KF (artifacts on the hot path). ------------------
        t_kf += kf_engine.predict(&mut kf, &mmat, &qdiag);
        t_kf += kf_engine.correct(&mut kf, &rows);

        // --- DD path: forecast, DyDD, parallel analysis. ----------------
        let backgrounds = [model.step(&x_dd), model.step(&x_static)];
        let mk_problem = |bg: &Vec<f64>| {
            ClsProblem::new(
                mesh.clone(),
                StateOp::Identity,
                bg.clone(),
                vec![1.0 / (sigma_b * sigma_b); n],
                obs.clone(),
            )
        };
        let part0 = Partition::uniform(n, p);
        let geom = IntervalGeometry::new(n, p);

        // dynamic: DyDD every cycle.
        let prob_dd = mk_problem(&backgrounds[0]);
        let t0 = Instant::now();
        let reb = rebalance(&geom, &part0, &prob_dd.obs, &DyddParams::default())?;
        t_dydd += t0.elapsed();
        min_balance = min_balance.min(reb.balance());
        let t0 = Instant::now();
        let sol = pool_dd.solve_on(&geom, &prob_dd, &reb.partition, &opts)?;
        t_dd += t0.elapsed();
        anyhow::ensure!(sol.converged, "DD analysis diverged at cycle {cycle}");
        x_dd = sol.x;

        // static control: uniform partition (no DyDD).
        let prob_st = mk_problem(&backgrounds[1]);
        let sol_st = pool_static.solve_on(&geom, &prob_st, &part0, &opts)?;
        x_static = sol_st.x;
        let census = obs.census(&mesh, &part0);
        worst_static_imbalance =
            worst_static_imbalance.min(dydd_da::dydd::balance_ratio(&census));

        // --- Metrics. ----------------------------------------------------
        rmse_kf += rmse(&kf.x, &truth);
        rmse_dd += rmse(&x_dd, &truth);
        rmse_static += rmse(&x_static, &truth);
        sum_err_paths += rmse(&x_dd, &x_static);

        if cycle % (cycles / 10).max(1) == 0 {
            println!(
                "cycle {cycle:4}  rmse(kf)={:.4}  rmse(dd)={:.4}  E={:.3}  census={:?}",
                rmse(&kf.x, &truth),
                rmse(&x_dd, &truth),
                reb.balance(),
                reb.census_after
            );
        }
    }

    let c = cycles as f64;
    println!("\n===== e2e summary =====");
    println!("cycles                  : {cycles}  (n={n}, m={m_obs}/cycle, p={p})");
    println!("mean RMSE vs truth      : KF {:.4} | DD-KF+DyDD {:.4} | DD static {:.4}", rmse_kf / c, rmse_dd / c, rmse_static / c);
    println!("mean |dd − static|      : {:.2e}  (same analysis, different partitions)", sum_err_paths / c);
    println!("worst census balance    : with DyDD {:.3} | static {:.3}", min_balance, worst_static_imbalance);
    println!("time: reference KF      : {:.2}s", t_kf.as_secs_f64());
    println!("time: DD analysis       : {:.2}s  (+ DyDD {:.3}s = {:.2}% overhead)", t_dd.as_secs_f64(), t_dydd.as_secs_f64(), 100.0 * t_dydd.as_secs_f64() / t_dd.as_secs_f64().max(1e-9));

    // The filters track the truth: analysis must beat the unassimilated
    // background error by a wide margin.
    assert!(rmse_dd / c < 0.05, "DD analysis should track the truth");
    assert!(rmse_kf / c < 0.05, "reference KF should track the truth");
    // Same CLS problem, partition-independent solution: paths agree.
    assert!(sum_err_paths / c < 1e-6, "DD analyses must be partition-independent");
    println!("e2e_assimilation OK");
    Ok(())
}
