//! 2-D DD-KF walkthrough: the full pipeline (workload → geometric DyDD →
//! parallel box-grid DD-KF → sequential-KF baseline) on [0, 1]².
//!
//!   cargo run --release --example ddkf_2d
//!
//! For each scenario the pipeline runs twice — once on the uniform box
//! grid and once after DyDD rebalancing — and reports the paper's
//! end-to-end metrics: error_DD-DA vs the sequential KF, the simulated
//! p-processor critical path T^p_crit, S^p_sim, and the balance ratio ℰ
//! before/after migration.

use dydd_da::config::ExperimentConfig;
use dydd_da::domain2d::ObsLayout2d;
use dydd_da::harness::run_experiment;
use dydd_da::util::timer::fmt_secs;

fn main() -> anyhow::Result<()> {
    for (title, layout, px, py) in [
        ("Gaussian blob, 2x2 boxes", ObsLayout2d::GaussianBlob, 2usize, 2usize),
        ("Diagonal band, 2x2 boxes", ObsLayout2d::DiagonalBand, 2, 2),
        ("Ring, 4x4 boxes", ObsLayout2d::Ring, 4, 4),
    ] {
        println!("== {title} ==");
        let mut cfg = ExperimentConfig::default();
        cfg.name = layout.name().into();
        cfg.dim = 2;
        cfg.n = 24; // 24 x 24 grid = 576 unknowns
        cfg.m = 400;
        cfg.px = px;
        cfg.py = py;
        cfg.layout2d = layout;
        cfg.seed = 42;

        cfg.dydd = false;
        let uniform = run_experiment(&cfg, true)?;
        cfg.dydd = true;
        let balanced = run_experiment(&cfg, true)?;

        let e_before = balanced.balance_before().unwrap();
        let e_after = balanced.balance().unwrap();
        for (tag, rep) in [("uniform ", &uniform), ("balanced", &balanced)] {
            println!(
                "  {tag}: iters={:>3} converged={} error_DD-DA={:.2e} \
                 T^p_crit={} S^p_sim={:.2}",
                rep.iters,
                rep.converged,
                rep.error_dd_da.unwrap(),
                fmt_secs(rep.t_critical.as_secs_f64()),
                rep.speedup_sim().unwrap(),
            );
        }
        println!("  DyDD: E = {e_before:.3} -> {e_after:.3}");

        // The paper's headline claims, asserted so CI smoke-tests the
        // whole 2-D path: fp-level error_DD-DA and non-degraded balance.
        for rep in [&uniform, &balanced] {
            let err = rep.error_dd_da.unwrap();
            assert!(rep.converged, "{title}: solve did not converge");
            assert!(err <= 1e-8, "{title}: error_DD-DA = {err:e}");
        }
        assert!(
            e_after >= e_before,
            "{title}: DyDD degraded balance ({e_before:.3} -> {e_after:.3})"
        );
        println!();
    }
    println!("ddkf_2d OK");
    Ok(())
}
