//! Multi-cycle assimilation with adaptive DyDD re-triggering: the
//! drifting-blob scenario under the three rebalance policies, in 1-D and
//! on the 2-D box grid.
//!
//!   cargo run --release --example dydd_cycles
//!
//! A Gaussian blob of observations translates across the domain over
//! K = 8 assimilation cycles while each cycle's DD-KF analysis feeds the
//! next cycle's background. The policies trade rebalance cost against
//! load balance:
//!
//!   * `never`        — static DD: the uniform initial partition decays
//!                      to ℰ ≈ 0.35 as the blob drifts away from it;
//!   * `every_cycle`  — DyDD before every solve: ℰ ≈ 0.99 throughout,
//!                      maximal T_DyDD overhead;
//!   * `threshold`    — DyDD only when ℰ drops below τ = 0.9: about half
//!                      the rebalances at nearly the every-cycle balance.
//!
//! The assertions at the bottom are the acceptance criteria of the cycle
//! driver, re-checked in release mode by CI.

use dydd_da::config::ExperimentConfig;
use dydd_da::domain::DriftLayout;
use dydd_da::domain2d::DriftLayout2d;
use dydd_da::dydd::RebalancePolicy;
use dydd_da::harness::cycles::{check_policy_acceptance, render_cycle_table};
use dydd_da::harness::{run_cycles, CycleReport};

const POLICIES: [RebalancePolicy; 3] = [
    RebalancePolicy::Never,
    RebalancePolicy::EveryCycle,
    RebalancePolicy::Threshold(0.9),
];

fn summarize(rep: &CycleReport) {
    println!("{}", render_cycle_table(rep).render());
    println!(
        "  => rebalances {}/{}  E_final {:.3}  E_mean {:.3}  moved {}  overhead {:.3}\n",
        rep.rebalances(),
        rep.records.len(),
        rep.final_balance(),
        rep.mean_balance(),
        rep.total_migration_volume(),
        rep.rebalance_overhead_fraction(),
    );
}

fn main() -> anyhow::Result<()> {
    // ---- 1-D: translating blob over an interval decomposition ----
    println!("== 1-D drifting blob: n=512, m=800, p=4, K=8 ==\n");
    let mut reports = Vec::new();
    for policy in POLICIES {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("cycles-1d-{}", policy.name());
        cfg.n = 512;
        cfg.m = 800;
        cfg.p = 4;
        cfg.cycles = 8;
        cfg.seed = 42;
        cfg.drift = DriftLayout::TranslatingBlob;
        cfg.cycle_policy = policy;
        let rep = run_cycles(&cfg, true)?;
        for r in &rep.records {
            let err = r.error_dd_da.unwrap();
            assert!(err < 1e-8, "cycle {}: error_DD-DA = {err:e}", r.cycle);
        }
        summarize(&rep);
        reports.push(rep);
    }
    check_policy_acceptance(&reports[0], &reports[1], &reports[2])?;

    // ---- 2-D: the same story on a box grid ----
    println!("== 2-D drifting blob: 48x48 grid, m=800, 2x2 boxes, K=8 ==\n");
    let mut reports2d = Vec::new();
    for policy in POLICIES {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("cycles-2d-{}", policy.name());
        cfg.dim = 2;
        cfg.n = 48;
        cfg.m = 800;
        cfg.px = 2;
        cfg.py = 2;
        cfg.cycles = 8;
        cfg.seed = 42;
        cfg.drift2d = DriftLayout2d::TranslatingBlob;
        cfg.cycle_policy = policy;
        // The sequential-KF baseline on 2304 unknowns x 8 cycles is the
        // only expensive part; the per-cycle solver agreement is already
        // asserted by the test suite, so the smoke test skips it.
        let rep = run_cycles(&cfg, false)?;
        summarize(&rep);
        reports2d.push(rep);
    }
    check_policy_acceptance(&reports2d[0], &reports2d[1], &reports2d[2])?;

    println!("dydd_cycles OK");
    Ok(())
}
